//! ARD squared-exponential covariance function — the paper's Section 6
//! kernel, shared convention with `python/compile/model.py`:
//!
//! `σ_xx' = sf2 · exp(-0.5 · Σ_i ((x_i - x'_i)/ls_i)²) + sn2 · δ_xx'`
//!
//! Same-set covariance blocks carry `+ sn2·I`; cross-set blocks do not.
//! Blocks headed for a Cholesky also get a relative jitter
//! `JITTER_SCALE · sf2 · I` — identical constants on both language sides
//! so native and PJRT paths agree to float precision.

use crate::linalg::simd::exp::{se_apply, se_point};
use crate::linalg::simd::mixed::{axpy_wide, MatF32};
use crate::linalg::{gemm_into, simd, LinalgCtx, Mat};

/// Relative jitter applied before factorization (== python JITTER_SCALE).
pub const JITTER_SCALE: f64 = 1e-8;

/// Precompiled cross-covariance map against a *fixed* set of source
/// rows — the query-independent half of `k(X_q, sources)` hoisted out
/// of the per-batch path.
///
/// [`SeArd::gram_ctx`] pays the 1/ls row scaling and ‖x‖² norms of
/// *both* sides on every call; a `FeatureMap` bakes the source side
/// once (scaled rows stored transposed so the per-batch cross term is
/// a single [`gemm_into`] with no transpose copy, plus the cached
/// norms), leaving only the query-side scaling, one GEMM and the
/// banded exp per batch. [`FeatureMap::fill`] output is
/// **bitwise-identical** to concatenating [`SeArd::cov_cross_ctx`]
/// against each source (tested): same scaling products, same 4-wide
/// k-grouped cross term, same `‖q‖² + ‖s‖² − 2·q·s` expression.
#[derive(Debug, Clone)]
pub struct FeatureMap {
    inv_ls: Vec<f64>,
    sf2: f64,
    /// Scaled source rows, transposed: (d × p).
    xt: Mat,
    /// Squared norms of the scaled source rows (p).
    sq: Vec<f64>,
}

/// Reusable per-call buffers for [`FeatureMap::fill`]. Steady-state
/// calls with stable batch shapes allocate nothing.
#[derive(Debug, Clone)]
pub struct FeatureScratch {
    qs: Mat,
    qsq: Vec<f64>,
}

impl FeatureScratch {
    #[must_use]
    pub fn new() -> FeatureScratch {
        FeatureScratch { qs: Mat::zeros(0, 0), qsq: Vec::new() }
    }
}

impl Default for FeatureScratch {
    fn default() -> FeatureScratch {
        FeatureScratch::new()
    }
}

impl FeatureMap {
    /// Total feature dimension p = Σ source rows.
    #[must_use]
    pub fn p(&self) -> usize {
        self.xt.cols
    }

    /// Input dimensionality d.
    #[must_use]
    pub fn dim(&self) -> usize {
        self.inv_ls.len()
    }

    /// Fill `out` (resized to rows × p) with `k(q, sources)` for the
    /// row-major queries `q` (rows × d). Banded over query rows on the
    /// ctx's pool; pooled output is bitwise-identical to serial.
    pub fn fill(
        &self,
        ctx: &LinalgCtx,
        q: &[f64],
        rows: usize,
        out: &mut Mat,
        scratch: &mut FeatureScratch,
    ) {
        let d = self.dim();
        assert_eq!(q.len(), rows * d, "feature fill: query shape");
        let p = self.p();
        scratch.qs.resize_to(rows, d);
        for r in 0..rows {
            let src = &q[r * d..(r + 1) * d];
            let dst = scratch.qs.row_mut(r);
            for (c, v) in dst.iter_mut().enumerate() {
                *v = src[c] * self.inv_ls[c];
            }
        }
        scratch.qsq.resize(rows, 0.0);
        for r in 0..rows {
            scratch.qsq[r] =
                scratch.qs.row(r).iter().map(|v| v * v).sum();
        }
        // cross term q̃ · x̃ᵀ straight into the output buffer, then the
        // rank-1 corrections + exp rewrite it in place row-band-parallel.
        out.resize_to(rows, p);
        gemm_into(ctx, &scratch.qs, &self.xt, out);
        if rows == 0 || p == 0 {
            return;
        }
        let sf2 = self.sf2;
        // One tier read on the calling thread, captured into the band
        // jobs (forced tiers survive the fan-out).
        let tier = simd::active_tier();
        let ranges = ctx.ranges(rows, 8);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(ranges.len());
        let mut rest: &mut [f64] = &mut out.data[..];
        let qsq = &scratch.qsq;
        let sq2 = &self.sq;
        for &(lo, hi) in &ranges {
            let (band, tail) =
                std::mem::take(&mut rest).split_at_mut((hi - lo) * p);
            rest = tail;
            jobs.push(Box::new(move || {
                for (r, krow) in band.chunks_mut(p).enumerate() {
                    se_apply(tier, sf2, qsq[lo + r], sq2, krow);
                }
            }));
        }
        ctx.run_jobs(jobs);
    }

    /// Allocating convenience wrapper around [`FeatureMap::fill`].
    #[must_use]
    pub fn features(&self, ctx: &LinalgCtx, xu: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        let mut scratch = FeatureScratch::default();
        self.fill(ctx, &xu.data, xu.rows, &mut out, &mut scratch);
        out
    }

    /// Demote to the mixed-precision serve form (f32-stored sources;
    /// see [`FeatureMapF32`]).
    #[must_use]
    pub fn demote(&self) -> FeatureMapF32 {
        let xt = MatF32::from_mat(&self.xt);
        // Norms recomputed from the *demoted* rows so the
        // ‖q‖²+‖s‖²−2·q·s expansion stays internally consistent (the
        // clamp at 0 then still fires exactly at q = s).
        let (d, p) = (xt.rows, xt.cols);
        let sq: Vec<f64> = (0..p)
            .map(|j| {
                (0..d)
                    .map(|c| {
                        let v = xt.data[c * p + j] as f64;
                        v * v
                    })
                    .sum()
            })
            .collect();
        FeatureMapF32 { inv_ls: self.inv_ls.clone(), sf2: self.sf2, xt, sq }
    }
}

/// Mixed-precision sibling of [`FeatureMap`]: the scaled source matrix
/// is stored in **f32** (halving the DRAM traffic that dominates the
/// serve-path feature build) while every reduction accumulates in
/// **f64** — the cross term is a widening GEMV sweep and the banded SE
/// transform runs on f64 rows before demoting the finished features to
/// f32 for the downstream f32-storage operators. The only error vs
/// [`FeatureMap`] is the one-time f32 rounding of the stored sources
/// and of the final feature values (≤2⁻²⁴ relative each); the serve
/// budget is asserted in `gp::predictor`.
#[derive(Debug, Clone)]
pub struct FeatureMapF32 {
    inv_ls: Vec<f64>,
    sf2: f64,
    /// Demoted scaled source rows, transposed: (d × p).
    xt: MatF32,
    /// Squared norms of the demoted scaled source rows (p).
    sq: Vec<f64>,
}

impl FeatureMapF32 {
    /// Total feature dimension p.
    #[must_use]
    pub fn p(&self) -> usize {
        self.xt.cols
    }

    /// Input dimensionality d.
    #[must_use]
    pub fn dim(&self) -> usize {
        self.inv_ls.len()
    }

    /// Fill `out` (resized to rows × p) with `k(q, sources)` in f32
    /// storage. Banded over query rows; pooled ≡ serial bitwise (each
    /// row's value depends only on its own inputs). Each band job
    /// carries one p-length f64 scratch row (the f32 mode trades this
    /// small per-call allocation for halved streaming traffic).
    pub fn fill(
        &self,
        ctx: &LinalgCtx,
        q: &[f64],
        rows: usize,
        out: &mut MatF32,
        scratch: &mut FeatureScratch,
    ) {
        let d = self.dim();
        assert_eq!(q.len(), rows * d, "feature fill f32: query shape");
        let p = self.p();
        scratch.qs.resize_to(rows, d);
        for r in 0..rows {
            let src = &q[r * d..(r + 1) * d];
            let dst = scratch.qs.row_mut(r);
            for (c, v) in dst.iter_mut().enumerate() {
                *v = src[c] * self.inv_ls[c];
            }
        }
        scratch.qsq.resize(rows, 0.0);
        for r in 0..rows {
            scratch.qsq[r] =
                scratch.qs.row(r).iter().map(|v| v * v).sum();
        }
        out.resize_to(rows, p);
        if rows == 0 || p == 0 {
            return;
        }
        let sf2 = self.sf2;
        let tier = simd::active_tier();
        let ranges = ctx.ranges(rows, 8);
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(ranges.len());
        let mut rest: &mut [f32] = &mut out.data[..];
        let qs = &scratch.qs;
        let qsq = &scratch.qsq;
        let (xt, sq2) = (&self.xt, &self.sq);
        for &(lo, hi) in &ranges {
            let (band, tail) =
                std::mem::take(&mut rest).split_at_mut((hi - lo) * p);
            rest = tail;
            jobs.push(Box::new(move || {
                let mut krow = vec![0.0f64; p];
                for (r, orow) in band.chunks_mut(p).enumerate() {
                    // widening cross term q̃ · x̃ᵀ (f32 sources, f64 acc)
                    krow.fill(0.0);
                    let qrow = qs.row(lo + r);
                    for (c, &qc) in qrow.iter().enumerate() {
                        axpy_wide(qc, xt.row(c), &mut krow);
                    }
                    se_apply(tier, sf2, qsq[lo + r], sq2, &mut krow);
                    for (o, &v) in orow.iter_mut().zip(krow.iter()) {
                        *o = v as f32;
                    }
                }
            }));
        }
        ctx.run_jobs(jobs);
    }
}

/// Hyperparameters of the ARD squared-exponential kernel, stored in log
/// space (the MLE optimizer works on this vector unconstrained).
#[derive(Debug, Clone, PartialEq)]
pub struct SeArd {
    pub log_ls: Vec<f64>,
    pub log_sf2: f64,
    pub log_sn2: f64,
}

impl SeArd {
    /// Isotropic constructor: all `d` length-scales equal `ls`.
    pub fn isotropic(d: usize, ls: f64, sf2: f64, sn2: f64) -> SeArd {
        SeArd {
            log_ls: vec![ls.ln(); d],
            log_sf2: sf2.ln(),
            log_sn2: sn2.ln(),
        }
    }

    pub fn dim(&self) -> usize {
        self.log_ls.len()
    }

    pub fn sf2(&self) -> f64 {
        self.log_sf2.exp()
    }

    pub fn sn2(&self) -> f64 {
        self.log_sn2.exp()
    }

    /// Jitter magnitude used before Cholesky factorizations.
    pub fn jitter(&self) -> f64 {
        JITTER_SCALE * self.sf2()
    }

    /// Prior variance of one (noisy) output: sf2 + sn2.
    pub fn prior_var(&self) -> f64 {
        self.sf2() + self.sn2()
    }

    /// Flatten to the artifact hyp-vector layout `[log_ls.., log_sf2,
    /// log_sn2]` consumed by the AOT graphs.
    pub fn to_vec(&self) -> Vec<f64> {
        let mut v = self.log_ls.clone();
        v.push(self.log_sf2);
        v.push(self.log_sn2);
        v
    }

    /// Inverse of [`Self::to_vec`].
    pub fn from_vec(v: &[f64]) -> SeArd {
        assert!(v.len() >= 3, "hyp vector too short");
        let d = v.len() - 2;
        SeArd {
            log_ls: v[..d].to_vec(),
            log_sf2: v[d],
            log_sn2: v[d + 1],
        }
    }

    /// Noise-free kernel value k(x, x'). Uses the scalar libm oracle
    /// ([`se_point`]) in every SIMD tier — the pointwise path is never
    /// hot, and keeping it on libm preserves `k(a,a) ≈ sf2` exactly.
    pub fn k(&self, x1: &[f64], x2: &[f64]) -> f64 {
        debug_assert_eq!(x1.len(), self.dim());
        debug_assert_eq!(x2.len(), self.dim());
        let mut s = 0.0;
        for i in 0..x1.len() {
            let diff = (x1[i] - x2[i]) * (-self.log_ls[i]).exp();
            s += diff * diff;
        }
        se_point(self.sf2(), s)
    }

    /// Cross-covariance block Σ_{X1 X2} (no noise, no jitter).
    pub fn cov_cross(&self, x1: &Mat, x2: &Mat) -> Mat {
        self.gram(x1, x2)
    }

    /// [`Self::cov_cross`] with explicit execution context.
    pub fn cov_cross_ctx(&self, ctx: &LinalgCtx, x1: &Mat, x2: &Mat) -> Mat {
        self.gram_ctx(ctx, x1, x2)
    }

    /// Same-set covariance block Σ_{XX} = K + sn2·I (+ jitter if
    /// `for_chol`), matching `model.cov(..., same=True)`.
    pub fn cov_same(&self, x: &Mat, for_chol: bool) -> Mat {
        self.cov_same_ctx(&LinalgCtx::serial(), x, for_chol)
    }

    /// [`Self::cov_same`] with explicit execution context.
    pub fn cov_same_ctx(
        &self,
        ctx: &LinalgCtx,
        x: &Mat,
        for_chol: bool,
    ) -> Mat {
        let mut k = self.gram_ctx(ctx, x, x);
        let bump = self.sn2() + if for_chol { self.jitter() } else { 0.0 };
        k.add_diag(bump);
        k
    }

    /// Diagonal of Σ_XX: sf2 + sn2 per row.
    pub fn cov_same_diag(&self, n: usize) -> Vec<f64> {
        vec![self.prior_var(); n]
    }

    /// Compile a [`FeatureMap`] over the concatenated rows of
    /// `sources` (e.g. `[S]` for PITC's `k(u, S)`, `[S, X_m]` for
    /// pPIC's stacked `[k(u,S) k(u,X_m)]` features): scales and
    /// transposes the source rows once and caches their norms, so
    /// every subsequent batch pays only the query-side work.
    #[must_use]
    pub fn feature_map(&self, sources: &[&Mat]) -> FeatureMap {
        let d = self.dim();
        let inv_ls: Vec<f64> =
            self.log_ls.iter().map(|l| (-l).exp()).collect();
        let p: usize = sources.iter().map(|x| x.rows).sum();
        let mut scaled = Mat::zeros(p, d);
        let mut row = 0;
        for x in sources {
            assert_eq!(x.cols, d, "feature_map source dim");
            for r in 0..x.rows {
                let src = x.row(r);
                let dst = scaled.row_mut(row);
                for (c, v) in dst.iter_mut().enumerate() {
                    *v = src[c] * inv_ls[c];
                }
                row += 1;
            }
        }
        let sq: Vec<f64> = (0..p)
            .map(|i| scaled.row(i).iter().map(|v| v * v).sum())
            .collect();
        FeatureMap { inv_ls, sf2: self.sf2(), xt: scaled.transpose(), sq }
    }

    /// Dense noise-free Gram matrix between row sets (serial ctx). See
    /// [`Self::gram_ctx`].
    pub fn gram(&self, x1: &Mat, x2: &Mat) -> Mat {
        self.gram_ctx(&LinalgCtx::serial(), x1, x2)
    }

    /// Dense noise-free Gram matrix between row sets, vectorized via
    /// the ‖x‖² + ‖x′‖² − 2·x·x′ expansion — mirrors the L1 Pallas
    /// kernel tile body. Scales inputs by 1/ls once, computes the cross
    /// term as one blocked GEMM on `ctx`, then applies the rank-1
    /// norm corrections + exp over row bands on the ctx's pool (the
    /// exp pass is the dominant cost for small d). Banding is
    /// element-disjoint: pooled output is bitwise-identical to serial.
    pub fn gram_ctx(&self, ctx: &LinalgCtx, x1: &Mat, x2: &Mat) -> Mat {
        assert_eq!(x1.cols, self.dim(), "x1 dim");
        assert_eq!(x2.cols, self.dim(), "x2 dim");
        let inv_ls: Vec<f64> = self.log_ls.iter().map(|l| (-l).exp()).collect();
        let scale_rows = |x: &Mat| -> Mat {
            let mut s = x.clone();
            for r in 0..s.rows {
                let row = s.row_mut(r);
                for (c, v) in row.iter_mut().enumerate() {
                    *v *= inv_ls[c];
                }
            }
            s
        };
        let s1 = scale_rows(x1);
        let s2 = scale_rows(x2);
        let sq1: Vec<f64> = (0..s1.rows)
            .map(|i| s1.row(i).iter().map(|v| v * v).sum())
            .collect();
        let sq2: Vec<f64> = (0..s2.rows)
            .map(|i| s2.row(i).iter().map(|v| v * v).sum())
            .collect();
        // The cross-term matrix becomes the output in place: each band
        // row holds q̃·s̃ᵀ on entry and the kernel value on exit (the
        // shared [`se_apply`] transform — same expression the seed
        // loop used, vectorized on AVX tiers).
        let mut k = crate::linalg::gemm_nt(ctx, &s1, &s2);
        let sf2 = self.sf2();
        let n2 = x2.rows;
        if n2 == 0 || x1.rows == 0 {
            return k;
        }
        let tier = simd::active_tier();
        {
            let ranges = ctx.ranges(x1.rows, 8);
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(ranges.len());
            let mut rest: &mut [f64] = &mut k.data[..];
            for &(lo, hi) in &ranges {
                let (band, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * n2);
                rest = tail;
                let sq1b = &sq1[lo..hi];
                let sq2r = &sq2;
                jobs.push(Box::new(move || {
                    for (r, krow) in band.chunks_mut(n2).enumerate() {
                        se_apply(tier, sf2, sq1b[r], sq2r, krow);
                    }
                }));
            }
            ctx.run_jobs(jobs);
        }
        k
    }

    /// Gram matrix plus its gradients w.r.t. every log-hyperparameter.
    ///
    /// Returns `(K, dK)` where `dK[i]` for `i < d` is ∂K/∂log_ls_i,
    /// `dK[d]` is ∂K/∂log_sf2 and `dK[d+1]` is ∂K/∂log_sn2 (same-set
    /// noise derivative = sn2·I when `same`). Used by the MLE optimizer.
    pub fn gram_with_grads(&self, x1: &Mat, x2: &Mat, same: bool) -> (Mat, Vec<Mat>) {
        self.gram_with_grads_ctx(&LinalgCtx::serial(), x1, x2, same)
    }

    /// [`Self::gram_with_grads`] with explicit execution context: the
    /// Gram evaluation routes through [`Self::gram_ctx`] (blocked GEMM +
    /// banded exp on the ctx). The per-hyper elementwise passes stay
    /// serial — this is the reference path for the trace-free gradient
    /// evaluators ([`Self::grad_dots`],
    /// `gp::likelihood::nlml_and_grad_ctx`), which never materialize
    /// these dK matrices on hot paths.
    pub fn gram_with_grads_ctx(
        &self,
        ctx: &LinalgCtx,
        x1: &Mat,
        x2: &Mat,
        same: bool,
    ) -> (Mat, Vec<Mat>) {
        let d = self.dim();
        let k0 = self.gram_ctx(ctx, x1, x2); // noise-free
        let mut grads = Vec::with_capacity(d + 2);
        let inv_ls2: Vec<f64> =
            self.log_ls.iter().map(|l| (-2.0 * l).exp()).collect();
        for c in 0..d {
            // ∂K/∂log_ls_c = K ∘ (x1_c - x2_c)² / ls_c²
            let mut g = k0.clone();
            for i in 0..x1.rows {
                for j in 0..x2.rows {
                    let diff = x1[(i, c)] - x2[(j, c)];
                    g[(i, j)] *= diff * diff * inv_ls2[c];
                }
            }
            grads.push(g);
        }
        // ∂K/∂log_sf2 = K (noise-free part)
        grads.push(k0.clone());
        // ∂K/∂log_sn2 = sn2 · I on same-set blocks, 0 otherwise
        let mut gn = Mat::zeros(x1.rows, x2.rows);
        if same {
            let sn2 = self.sn2();
            let n = x1.rows.min(x2.rows);
            for i in 0..n {
                gn[(i, i)] = sn2;
            }
        }
        grads.push(gn);

        let mut k = k0;
        if same {
            k.add_diag(self.sn2());
        }
        (k, grads)
    }

    /// `Σ_ij coef_ij · ∂Block_ij/∂θ_p` for every log-hyperparameter,
    /// where Block is the noise-free gram `k0` between `x1` and `x2`
    /// plus, when `same`, the `(sn2 + jitter)·I` diagonal.
    ///
    /// The trace-free gradient core shared by the exact-GP NLML
    /// (`gp::likelihood`) and distributed PITC training (`train::nlml`).
    /// Uses the ‖x‖² expansion: with `G = coef ∘ K₀`,
    /// `Σ_ij G_ij (x1_ic − x2_jc)² = q1ᵀ·rowsum(G) + q2ᵀ·colsum(G) −
    /// 2·x1ᵀGx2` (q = elementwise squares), so per-hyper cost is one
    /// matvec and no dK matrix is ever materialized. The sf2 slot is
    /// `Σ G` (+ `jitter·tr coef` when `same` — jitter's sf2-dependence,
    /// `jitter = JITTER_SCALE·sf2`, is included so analytic gradients
    /// match finite differences of the jittered objective) and the sn2
    /// slot `sn2·tr coef`.
    pub fn grad_dots(
        &self,
        coef: &Mat,
        k0: &Mat,
        x1: &Mat,
        x2: &Mat,
        same: bool,
    ) -> Vec<f64> {
        let d = self.dim();
        let (n1, n2) = (x1.rows, x2.rows);
        assert_eq!((coef.rows, coef.cols), (n1, n2), "coef shape");
        assert_eq!((k0.rows, k0.cols), (n1, n2), "k0 shape");
        let mut g = coef.clone();
        for (gv, kv) in g.data.iter_mut().zip(k0.data.iter()) {
            *gv *= kv;
        }
        let mut rrow = vec![0.0; n1];
        let mut rcol = vec![0.0; n2];
        for i in 0..n1 {
            let row = g.row(i);
            let mut sum = 0.0;
            for (j, &v) in row.iter().enumerate() {
                sum += v;
                rcol[j] += v;
            }
            rrow[i] = sum;
        }
        let mut out = vec![0.0; d + 2];
        for (cdim, out_c) in out.iter_mut().enumerate().take(d) {
            let inv_ls2 = (-2.0 * self.log_ls[cdim]).exp();
            let x2c: Vec<f64> = (0..n2).map(|j| x2[(j, cdim)]).collect();
            let gx = crate::linalg::matvec(&g, &x2c);
            let mut s1 = 0.0;
            let mut cross = 0.0;
            for i in 0..n1 {
                let xi = x1[(i, cdim)];
                s1 += xi * xi * rrow[i];
                cross += xi * gx[i];
            }
            let mut s2 = 0.0;
            for (j, &xj) in x2c.iter().enumerate() {
                s2 += xj * xj * rcol[j];
            }
            *out_c = inv_ls2 * (s1 + s2 - 2.0 * cross);
        }
        out[d] = rrow.iter().sum();
        if same {
            let tr: f64 = (0..n1.min(n2)).map(|i| coef[(i, i)]).sum();
            out[d] += self.jitter() * tr;
            out[d + 1] = self.sn2() * tr;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_close;

    fn rand_x(g: &mut Gen, n: usize, d: usize) -> Mat {
        Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0))
    }

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -1.0, 1.0),
            log_sf2: g.f64_in(-1.0, 1.0),
            log_sn2: g.f64_in(-4.0, -1.0),
        }
    }

    #[test]
    fn gram_matches_pointwise_k() {
        prop_check("gram-pointwise", 16, |g| {
            let (n1, n2, d) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 5));
            let hyp = rand_hyp(g, d);
            let x1 = rand_x(g, n1, d);
            let x2 = rand_x(g, n2, d);
            let k = hyp.gram(&x1, &x2);
            for i in 0..n1 {
                for j in 0..n2 {
                    assert_close(k[(i, j)], hyp.k(x1.row(i), x2.row(j)),
                                 1e-12, 1e-12);
                }
            }
        });
    }

    /// Pooled Gram evaluation (banded exp pass + pooled GEMM) is
    /// bitwise-identical to the serial path.
    #[test]
    fn gram_pooled_bitwise_matches_serial() {
        use crate::linalg::LinalgCtx;
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        prop_check("gram-pooled-serial", 6, |g| {
            let (n1, n2, d) =
                (g.usize_in(1, 60), g.usize_in(1, 60), g.usize_in(1, 6));
            let hyp = rand_hyp(g, d);
            let x1 = rand_x(g, n1, d);
            let x2 = rand_x(g, n2, d);
            let serial = hyp.gram(&x1, &x2);
            let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
            let pooled = hyp.gram_ctx(&ctx, &x1, &x2);
            assert_eq!(serial, pooled);
        });
    }

    #[test]
    fn cov_same_adds_noise_on_diagonal() {
        prop_check("cov-same-noise", 8, |g| {
            let (n, d) = (g.usize_in(1, 8), g.usize_in(1, 4));
            let hyp = rand_hyp(g, d);
            let x = rand_x(g, n, d);
            let plain = hyp.gram(&x, &x);
            let with_noise = hyp.cov_same(&x, false);
            for i in 0..n {
                assert_close(with_noise[(i, i)] - plain[(i, i)], hyp.sn2(),
                             1e-12, 1e-12);
            }
            let for_chol = hyp.cov_same(&x, true);
            assert_close(for_chol[(0, 0)] - with_noise[(0, 0)], hyp.jitter(),
                         1e-9, 1e-15);
        });
    }

    #[test]
    fn kernel_bounds_and_symmetry() {
        prop_check("kernel-bounds", 16, |g| {
            let d = g.usize_in(1, 5);
            let hyp = rand_hyp(g, d);
            let a = g.uniform_vec(d, -3.0, 3.0);
            let b = g.uniform_vec(d, -3.0, 3.0);
            let kab = hyp.k(&a, &b);
            assert!(kab > 0.0 && kab <= hyp.sf2() + 1e-15);
            assert_close(kab, hyp.k(&b, &a), 1e-15, 1e-15);
            assert_close(hyp.k(&a, &a), hyp.sf2(), 1e-12, 1e-15);
        });
    }

    #[test]
    fn hyp_vec_roundtrip() {
        let hyp = SeArd {
            log_ls: vec![0.1, -0.2, 0.3],
            log_sf2: 0.5,
            log_sn2: -2.0,
        };
        assert_eq!(SeArd::from_vec(&hyp.to_vec()), hyp);
        assert_eq!(hyp.to_vec().len(), 5);
    }

    #[test]
    fn grads_match_finite_differences() {
        prop_check("kernel-grads-fd", 8, |g| {
            let (n, d) = (g.usize_in(2, 6), g.usize_in(1, 3));
            let hyp = rand_hyp(g, d);
            let x = rand_x(g, n, d);
            let (_, grads) = hyp.gram_with_grads(&x, &x, true);
            let eps = 1e-6;
            for p in 0..d + 2 {
                let mut hp = hyp.clone();
                let mut hm = hyp.clone();
                match p {
                    _ if p < d => {
                        hp.log_ls[p] += eps;
                        hm.log_ls[p] -= eps;
                    }
                    _ if p == d => {
                        hp.log_sf2 += eps;
                        hm.log_sf2 -= eps;
                    }
                    _ => {
                        hp.log_sn2 += eps;
                        hm.log_sn2 -= eps;
                    }
                }
                let kp = hp.cov_same(&x, false);
                let km = hm.cov_same(&x, false);
                for i in 0..n {
                    for j in 0..n {
                        let fd = (kp[(i, j)] - km[(i, j)]) / (2.0 * eps);
                        assert_close(grads[p][(i, j)], fd, 1e-5, 1e-7);
                    }
                }
            }
        });
    }

    /// `grad_dots` equals explicit elementwise dots against the
    /// materialized gradient matrices (the thing it exists to avoid).
    #[test]
    fn grad_dots_matches_materialized_grads() {
        prop_check("grad-dots-vs-materialized", 10, |g| {
            let (n1, n2, d) =
                (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 4));
            let hyp = rand_hyp(g, d);
            let x1 = rand_x(g, n1, d);
            let x2 = rand_x(g, n2, d);
            let coef = Mat::from_vec(n1, n2, g.normal_vec(n1 * n2));
            let k0 = hyp.gram(&x1, &x2);
            // same = false: the materialized grads carry no jitter term,
            // so the comparison is exact slot-for-slot
            let dots = hyp.grad_dots(&coef, &k0, &x1, &x2, false);
            let (_, grads) = hyp.gram_with_grads(&x1, &x2, false);
            for (p, dk) in grads.iter().enumerate() {
                let want: f64 = coef
                    .data
                    .iter()
                    .zip(dk.data.iter())
                    .map(|(a, b)| a * b)
                    .sum();
                assert_close(dots[p], want, 1e-9, 1e-10);
            }
            // same = true adds exactly jitter·tr and sn2·tr
            let dots_same = hyp.grad_dots(&coef, &k0, &x1, &x2, true);
            let tr: f64 = (0..n1.min(n2)).map(|i| coef[(i, i)]).sum();
            assert_close(dots_same[d], dots[d] + hyp.jitter() * tr,
                         1e-12, 1e-12);
            assert_close(dots_same[d + 1], hyp.sn2() * tr, 1e-12, 1e-12);
        });
    }

    /// Ctx-routed gradient evaluation is bitwise-identical to serial
    /// (the Gram underneath is pooled-banded; the grad passes are the
    /// same instruction sequence either way).
    #[test]
    fn grads_pooled_bitwise_matches_serial() {
        use crate::linalg::LinalgCtx;
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        prop_check("gram-grads-pooled-serial", 6, |g| {
            let (n1, n2, d) =
                (g.usize_in(1, 40), g.usize_in(1, 40), g.usize_in(1, 4));
            let hyp = rand_hyp(g, d);
            let x1 = rand_x(g, n1, d);
            let x2 = rand_x(g, n2, d);
            let same = n1 == n2 && g.bool();
            let (k_s, g_s) = hyp.gram_with_grads(&x1, &x2, same);
            let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
            let (k_p, g_p) = hyp.gram_with_grads_ctx(&ctx, &x1, &x2, same);
            assert_eq!(k_s, k_p);
            assert_eq!(g_s, g_p);
        });
    }

    /// FeatureMap::fill over concatenated sources is bitwise-identical
    /// to the per-source cov_cross_ctx blocks laid side by side — the
    /// serve path's feature build changes no numbers.
    #[test]
    fn feature_map_bitwise_matches_cov_cross() {
        prop_check("feature-map-bitwise", 10, |g| {
            let d = g.usize_in(1, 5);
            let (s, b, u) =
                (g.usize_in(1, 20), g.usize_in(1, 20), g.usize_in(1, 15));
            let hyp = rand_hyp(g, d);
            let xs = rand_x(g, s, d);
            let xm = rand_x(g, b, d);
            let xu = rand_x(g, u, d);
            let fm = hyp.feature_map(&[&xs, &xm]);
            assert_eq!(fm.p(), s + b);
            let ctx = LinalgCtx::serial();
            let got = fm.features(&ctx, &xu);
            let want_s = hyp.cov_cross_ctx(&ctx, &xu, &xs);
            let want_m = hyp.cov_cross_ctx(&ctx, &xu, &xm);
            for i in 0..u {
                assert_eq!(&got.row(i)[..s], want_s.row(i), "row {i} S");
                assert_eq!(&got.row(i)[s..], want_m.row(i), "row {i} M");
            }
        });
    }

    /// Reusing one FeatureScratch across differently-shaped batches
    /// gives the same numbers as fresh buffers (the serve-loop reuse
    /// contract), and a padded batch's retained rows equal the
    /// unpadded batch's rows bitwise.
    #[test]
    fn feature_scratch_reuse_and_padding_transparent() {
        let mut rng = crate::util::Pcg64::seed(9);
        let d = 3;
        let hyp = SeArd::isotropic(d, 0.9, 1.2, 0.05);
        let xs = Mat::from_vec(6, d, rng.normals(6 * d));
        let fm = hyp.feature_map(&[&xs]);
        let ctx = LinalgCtx::serial();
        let mut scratch = FeatureScratch::new();
        let mut out = Mat::zeros(0, 0);
        for rows in [4usize, 1, 7, 4] {
            let q = rng.normals(rows * d);
            fm.fill(&ctx, &q, rows, &mut out, &mut scratch);
            let fresh = fm.features(&ctx, &Mat::from_vec(rows, d, q.clone()));
            assert_eq!(out, fresh, "rows={rows}");
            // pad by repeating the first row: retained rows unchanged
            let mut padded_q = q.clone();
            padded_q.extend_from_slice(&q[..d]);
            let mut padded = Mat::zeros(0, 0);
            fm.fill(&ctx, &padded_q, rows + 1, &mut padded, &mut scratch);
            for r in 0..rows {
                assert_eq!(padded.row(r), out.row(r));
            }
        }
    }

    /// Every exp call site (gram_ctx, FeatureMap::fill, SeArd::k) is
    /// pinned to the scalar libm oracle under every supported SIMD
    /// tier: Portable bitwise (it *is* the seed expression), AVX tiers
    /// within the polynomial-exp tolerance.
    #[test]
    fn exp_call_sites_match_scalar_oracle_across_tiers() {
        use crate::linalg::SimdTier;
        for tier in SimdTier::available() {
            let _t = crate::linalg::force_tier(tier);
            prop_check(&format!("se-oracle-{}", tier.name()), 6, |g| {
                let d = g.usize_in(1, 5);
                let (n1, n2) = (g.usize_in(1, 30), g.usize_in(1, 30));
                let hyp = rand_hyp(g, d);
                let x1 = rand_x(g, n1, d);
                let x2 = rand_x(g, n2, d);
                let ctx = LinalgCtx::serial();
                let k = hyp.gram_ctx(&ctx, &x1, &x2);
                let fm = hyp.feature_map(&[&x2]);
                let f = fm.features(&ctx, &x1);
                for i in 0..n1 {
                    for j in 0..n2 {
                        let oracle = hyp.k(x1.row(i), x2.row(j));
                        // gram and features share se_apply → identical
                        assert_eq!(k[(i, j)], f[(i, j)], "gram vs fill");
                        // and both track the pointwise libm oracle
                        // (expansion vs diff form reassociation + the
                        // polynomial exp's ulp bound)
                        assert_close(k[(i, j)], oracle, 1e-10, 1e-12);
                    }
                }
            });
        }
    }

    /// The f32-storage feature map tracks the f64 map within the serve
    /// error budget, and its pooled fill is bitwise-identical to
    /// serial.
    #[test]
    fn feature_map_f32_tracks_f64_within_budget() {
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        prop_check("feature-map-f32", 8, |g| {
            let d = g.usize_in(1, 5);
            let (s, u) = (g.usize_in(1, 40), g.usize_in(1, 30));
            let hyp = rand_hyp(g, d);
            let xs = rand_x(g, s, d);
            let xu = rand_x(g, u, d);
            let fm = hyp.feature_map(&[&xs]);
            let fm32 = fm.demote();
            assert_eq!(fm32.p(), s);
            assert_eq!(fm32.dim(), d);
            let ctx = LinalgCtx::serial();
            let want = fm.features(&ctx, &xu);
            let mut got = MatF32::zeros(0, 0);
            let mut scratch = FeatureScratch::new();
            fm32.fill(&ctx, &xu.data, u, &mut got, &mut scratch);
            let sf2 = hyp.sf2();
            for i in 0..u {
                for j in 0..s {
                    let w = want[(i, j)];
                    let v = got.row(i)[j] as f64;
                    assert!(
                        (v - w).abs() <= 1e-4 * sf2.max(w.abs()),
                        "({i},{j}): {v} vs {w}"
                    );
                }
            }
            let pooled = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
            let mut got_p = MatF32::zeros(0, 0);
            fm32.fill(&pooled, &xu.data, u, &mut got_p, &mut scratch);
            assert_eq!(got.data, got_p.data, "pooled f32 fill bitwise");
        });
    }

    #[test]
    fn isotropic_constructor() {
        let hyp = SeArd::isotropic(4, 2.0, 1.5, 0.01);
        assert_eq!(hyp.dim(), 4);
        assert!((hyp.sf2() - 1.5).abs() < 1e-12);
        assert!((hyp.sn2() - 0.01).abs() < 1e-12);
        assert!(hyp.log_ls.iter().all(|&l| (l - 2.0f64.ln()).abs() < 1e-12));
    }
}
