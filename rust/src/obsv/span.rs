//! Phase-span tracing: RAII guards feeding a bounded ring-buffer
//! journal.
//!
//! A [`SpanGuard`] (from [`crate::obsv::span`]) notes the monotonic
//! start time, pushes itself on a thread-local parent stack, and on
//! drop appends one [`SpanRecord`] to the recording registry's
//! journal. Guards therefore nest naturally: fit → protocol phase →
//! collective → linalg call. The cluster simulator's phases are not
//! RAII-shaped (they are end-marks), so [`crate::obsv::emit_span_at`]
//! also accepts explicit start/end times and an explicit parent,
//! letting `Cluster::phase` synthesize the span covering
//! `[previous mark, now]` and re-parent the collective events that
//! happened inside it.
//!
//! The journal is a fixed-capacity ring ([`JOURNAL_CAP`]): when full,
//! the oldest record is dropped (and counted), never the newest —
//! snapshots stay bounded under unbounded serve loops. Span ids are
//! per-registry sequence numbers and never leave the process: the
//! snapshot exports the reconstructed *tree*, which is what makes two
//! seeded chaos replays bitwise-comparable.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use super::Registry;

/// Structured span field value.
#[derive(Clone, Debug, PartialEq)]
pub enum FieldValue {
    /// Deterministic integer payloads (bytes, machines, fault counts).
    U64(u64),
    /// Measured floating payloads (seconds) — dropped by the
    /// deterministic snapshot mode.
    F64(f64),
    /// Small string payloads (method names, phase labels).
    Str(String),
}

/// Parent selection for [`crate::obsv::emit_span_at`].
#[derive(Clone, Copy, Debug)]
pub enum Parent {
    /// The calling thread's innermost open [`SpanGuard`] (root if none).
    Current,
    /// An explicit span id previously returned by `emit_span_at`.
    Explicit(u64),
    /// Force a root span.
    Root,
}

/// One completed span in the journal.
#[derive(Clone, Debug)]
pub struct SpanRecord {
    /// Per-registry sequence number (never exported).
    pub id: u64,
    /// Parent span id, 0 for roots.
    pub parent: u64,
    /// Span name, e.g. `protocol.pPITC` or `phase.local_summary`.
    pub name: String,
    /// Monotonic nanoseconds since the registry epoch.
    pub start_ns: u64,
    /// Monotonic nanoseconds since the registry epoch.
    pub end_ns: u64,
    /// Structured fields attached at creation.
    pub fields: Vec<(&'static str, FieldValue)>,
}

/// Journal capacity; the oldest records are evicted (and counted) past
/// this.
pub const JOURNAL_CAP: usize = 4096;

/// Bounded ring buffer of completed spans.
pub(crate) struct Journal {
    inner: Mutex<JournalInner>,
}

struct JournalInner {
    records: VecDeque<SpanRecord>,
    dropped: u64,
}

impl Journal {
    pub(crate) fn new() -> Journal {
        Journal {
            inner: Mutex::new(JournalInner {
                records: VecDeque::new(),
                dropped: 0,
            }),
        }
    }

    pub(crate) fn push(&self, rec: SpanRecord) {
        let mut j = self.inner.lock().unwrap();
        if j.records.len() >= JOURNAL_CAP {
            j.records.pop_front();
            j.dropped += 1;
        }
        j.records.push_back(rec);
    }

    /// Copy out the journal (records in insertion order, drop count).
    pub(crate) fn contents(&self) -> (Vec<SpanRecord>, u64) {
        let j = self.inner.lock().unwrap();
        (j.records.iter().cloned().collect(), j.dropped)
    }
}

thread_local! {
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

pub(crate) fn current_parent() -> u64 {
    SPAN_STACK.with(|s| s.borrow().last().copied().unwrap_or(0))
}

/// RAII span: records `[creation, drop]` against the registry that was
/// recording at creation time. A no-op shell when telemetry is off.
pub struct SpanGuard {
    data: Option<SpanData>,
}

struct SpanData {
    reg: Arc<Registry>,
    id: u64,
    parent: u64,
    name: &'static str,
    start_ns: u64,
    fields: Vec<(&'static str, FieldValue)>,
}

impl SpanGuard {
    pub(crate) fn disabled() -> SpanGuard {
        SpanGuard { data: None }
    }

    pub(crate) fn open(reg: Arc<Registry>, name: &'static str) -> SpanGuard {
        let id = reg.next_span_id();
        let parent = current_parent();
        SPAN_STACK.with(|s| s.borrow_mut().push(id));
        let start_ns = reg.now_ns();
        SpanGuard {
            data: Some(SpanData {
                reg,
                id,
                parent,
                name,
                start_ns,
                fields: Vec::new(),
            }),
        }
    }

    /// Attach a deterministic integer field (builder-style).
    pub fn with_u64(mut self, key: &'static str, v: u64) -> SpanGuard {
        if let Some(d) = &mut self.data {
            d.fields.push((key, FieldValue::U64(v)));
        }
        self
    }

    /// Attach a measured floating field (builder-style).
    pub fn with_f64(mut self, key: &'static str, v: f64) -> SpanGuard {
        if let Some(d) = &mut self.data {
            d.fields.push((key, FieldValue::F64(v)));
        }
        self
    }

    /// Attach a string field (builder-style).
    pub fn with_str(mut self, key: &'static str, v: &str) -> SpanGuard {
        if let Some(d) = &mut self.data {
            d.fields.push((key, FieldValue::Str(v.to_string())));
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(d) = self.data.take() {
            SPAN_STACK.with(|s| {
                let mut s = s.borrow_mut();
                if s.last() == Some(&d.id) {
                    s.pop();
                } else {
                    // out-of-order drop (moved guard): drop quietly
                    s.retain(|&x| x != d.id);
                }
            });
            let end_ns = d.reg.now_ns();
            d.reg.journal().push(SpanRecord {
                id: d.id,
                parent: d.parent,
                name: d.name.to_string(),
                start_ns: d.start_ns,
                end_ns,
                fields: d.fields,
            });
        }
    }
}
