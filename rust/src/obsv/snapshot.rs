//! Snapshot + exporters: the [`TelemetrySnapshot`] API, its
//! deterministic JSON rendering (stable key order via [`crate::util::json`],
//! suitable for test pinning), and the Prometheus text format.
//!
//! Two modes: [`SnapshotMode::Full`] keeps everything; (timestamps,
//! latency histograms, measured-seconds fields), while
//! [`SnapshotMode::Deterministic`] keeps only what a seeded replay
//! reproduces bitwise — counters, gauges, count-unit histograms, and
//! the span *tree* (names, nesting, integer/string fields) without
//! timestamps. `tests/integration_faults.rs` pins a chaos run's
//! deterministic snapshot across two replays.

use std::collections::{BTreeMap, HashMap, HashSet};

use super::hist::{Histogram, Unit};
use super::span::{FieldValue, SpanRecord};
use super::Registry;
use crate::util::json::{obj, Json};

/// What survives into a snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SnapshotMode {
    /// Everything, including measured times.
    Full,
    /// Only seeded-replay-stable content (for bitwise pinning).
    Deterministic,
}

impl SnapshotMode {
    fn name(self) -> &'static str {
        match self {
            SnapshotMode::Full => "full",
            SnapshotMode::Deterministic => "deterministic",
        }
    }
}

/// Point-in-time view of one histogram.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    /// Unit name (`seconds` / `count`).
    pub unit: &'static str,
    /// Observation count.
    pub count: u64,
    /// Exact sum.
    pub sum: f64,
    /// Exact minimum.
    pub min: f64,
    /// Exact maximum.
    pub max: f64,
    /// Interpolated 50th percentile.
    pub p50: f64,
    /// Interpolated 95th percentile.
    pub p95: f64,
    /// Interpolated 99th percentile.
    pub p99: f64,
    /// Non-empty `(bucket index, count)` pairs.
    pub buckets: Vec<(usize, u64)>,
}

impl HistSnapshot {
    fn of(h: &Histogram) -> HistSnapshot {
        HistSnapshot {
            unit: h.unit().name(),
            count: h.count(),
            sum: h.sum(),
            min: h.min(),
            max: h.max(),
            p50: h.quantile(0.50),
            p95: h.quantile(0.95),
            p99: h.quantile(0.99),
            buckets: h.nonzero_buckets(),
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("unit", Json::from(self.unit)),
            ("count", Json::from(self.count as usize)),
            ("sum", Json::from(self.sum)),
            ("min", Json::from(self.min)),
            ("max", Json::from(self.max)),
            ("p50", Json::from(self.p50)),
            ("p95", Json::from(self.p95)),
            ("p99", Json::from(self.p99)),
            (
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|&(i, c)| {
                            Json::Arr(vec![Json::from(i), Json::from(c as usize)])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One node of the reconstructed span tree. Children appear in journal
/// order; raw span ids are never exported.
#[derive(Clone, Debug)]
pub struct SpanNode {
    /// Span name.
    pub name: String,
    /// Start (ns since registry epoch); `None` in deterministic mode.
    pub start_ns: Option<u64>,
    /// Duration in ns; `None` in deterministic mode.
    pub dur_ns: Option<u64>,
    /// Structured fields (deterministic mode drops `F64` fields).
    pub fields: Vec<(String, Json)>,
    /// Nested child spans.
    pub children: Vec<SpanNode>,
}

impl SpanNode {
    fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            vec![("name", Json::from(self.name.as_str()))];
        if let Some(s) = self.start_ns {
            pairs.push(("start_ns", Json::from(s as usize)));
        }
        if let Some(d) = self.dur_ns {
            pairs.push(("dur_ns", Json::from(d as usize)));
        }
        if !self.fields.is_empty() {
            let mut f = BTreeMap::new();
            for (k, v) in &self.fields {
                f.insert(k.clone(), v.clone());
            }
            pairs.push(("fields", Json::Obj(f)));
        }
        if !self.children.is_empty() {
            pairs.push((
                "children",
                Json::Arr(self.children.iter().map(SpanNode::to_json).collect()),
            ));
        }
        obj(pairs)
    }
}

/// A point-in-time copy of a registry: what `pgpr stats`, the
/// `--telemetry-out` flags, and the future socket front-end serve.
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    /// Mode this snapshot was taken in.
    pub mode: SnapshotMode,
    /// Monotonic counters by name.
    pub counters: BTreeMap<String, u64>,
    /// Gauges by name.
    pub gauges: BTreeMap<String, i64>,
    /// Histograms by name.
    pub hists: BTreeMap<String, HistSnapshot>,
    /// Root spans of the reconstructed tree, journal order.
    pub spans: Vec<SpanNode>,
    /// Spans evicted from the bounded journal.
    pub dropped_spans: u64,
}

impl TelemetrySnapshot {
    /// An empty snapshot (what you get with telemetry disabled).
    pub fn empty(mode: SnapshotMode) -> TelemetrySnapshot {
        TelemetrySnapshot {
            mode,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
            spans: Vec::new(),
            dropped_spans: 0,
        }
    }

    /// Stable-key-order JSON document (`pgpr-telemetry/1`).
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), Json::from(v as usize)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .iter()
            .map(|(k, &v)| (k.clone(), Json::from(v as f64)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .hists
            .iter()
            .map(|(k, h)| (k.clone(), h.to_json()))
            .collect();
        obj(vec![
            ("schema", Json::from("pgpr-telemetry/1")),
            ("mode", Json::from(self.mode.name())),
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(hists)),
            (
                "spans",
                Json::Arr(self.spans.iter().map(SpanNode::to_json).collect()),
            ),
            ("dropped_spans", Json::from(self.dropped_spans as usize)),
        ])
    }

    /// Prometheus text exposition: counters and gauges as-is,
    /// histograms as summaries (`{quantile="…"}`, `_sum`, `_count`).
    /// Metric names are prefixed `pgpr_` with non-alphanumerics mapped
    /// to `_`.
    pub fn to_prometheus(&self) -> String {
        fn mangle(name: &str) -> String {
            let mut m = String::with_capacity(name.len() + 5);
            m.push_str("pgpr_");
            for ch in name.chars() {
                m.push(if ch.is_ascii_alphanumeric() { ch } else { '_' });
            }
            m
        }
        let mut out = String::new();
        for (name, v) in &self.counters {
            let m = mangle(name);
            out.push_str(&format!("# TYPE {m} counter\n{m} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let m = mangle(name);
            out.push_str(&format!("# TYPE {m} gauge\n{m} {v}\n"));
        }
        for (name, h) in &self.hists {
            let m = mangle(name);
            out.push_str(&format!("# TYPE {m} summary\n"));
            for (q, v) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)] {
                out.push_str(&format!("{m}{{quantile=\"{q}\"}} {v}\n"));
            }
            out.push_str(&format!("{m}_sum {}\n{m}_count {}\n", h.sum, h.count));
        }
        out
    }
}

fn field_to_json(v: &FieldValue, mode: SnapshotMode) -> Option<Json> {
    match v {
        FieldValue::U64(u) => Some(Json::from(*u as usize)),
        FieldValue::Str(s) => Some(Json::from(s.as_str())),
        FieldValue::F64(f) => match mode {
            SnapshotMode::Full => Some(Json::from(*f)),
            SnapshotMode::Deterministic => None,
        },
    }
}

fn build_tree(records: &[SpanRecord], mode: SnapshotMode) -> Vec<SpanNode> {
    let ids: HashSet<u64> = records.iter().map(|r| r.id).collect();
    let mut children: HashMap<u64, Vec<usize>> = HashMap::new();
    let mut roots: Vec<usize> = Vec::new();
    for (i, r) in records.iter().enumerate() {
        if r.parent != 0 && ids.contains(&r.parent) {
            children.entry(r.parent).or_default().push(i);
        } else {
            roots.push(i);
        }
    }
    fn build(
        i: usize,
        records: &[SpanRecord],
        children: &HashMap<u64, Vec<usize>>,
        mode: SnapshotMode,
    ) -> SpanNode {
        let r = &records[i];
        let kids = children
            .get(&r.id)
            .map(|ks| {
                ks.iter().map(|&k| build(k, records, children, mode)).collect()
            })
            .unwrap_or_default();
        let (start_ns, dur_ns) = match mode {
            SnapshotMode::Full => {
                (Some(r.start_ns), Some(r.end_ns.saturating_sub(r.start_ns)))
            }
            SnapshotMode::Deterministic => (None, None),
        };
        SpanNode {
            name: r.name.clone(),
            start_ns,
            dur_ns,
            fields: r
                .fields
                .iter()
                .filter_map(|(k, v)| {
                    field_to_json(v, mode).map(|j| (k.to_string(), j))
                })
                .collect(),
            children: kids,
        }
    }
    roots
        .into_iter()
        .map(|i| build(i, records, &children, mode))
        .collect()
}

impl Registry {
    /// Take a [`TelemetrySnapshot`] of everything recorded so far.
    pub fn snapshot(&self, mode: SnapshotMode) -> TelemetrySnapshot {
        let counters = self
            .counters_view(|m| {
                m.iter()
                    .map(|(k, v)| {
                        (k.clone(), v.load(std::sync::atomic::Ordering::Relaxed))
                    })
                    .collect::<BTreeMap<_, _>>()
            });
        let gauges = self.gauges_view(|m| {
            m.iter()
                .map(|(k, v)| {
                    (k.clone(), v.load(std::sync::atomic::Ordering::Relaxed))
                })
                .collect::<BTreeMap<_, _>>()
        });
        let hists = self.hists_view(|m| {
            m.iter()
                .filter(|(_, h)| {
                    mode == SnapshotMode::Full || h.unit() != Unit::Seconds
                })
                .map(|(k, h)| (k.clone(), HistSnapshot::of(h)))
                .collect::<BTreeMap<_, _>>()
        });
        let (records, dropped) = self.journal().contents();
        TelemetrySnapshot {
            mode,
            counters,
            gauges,
            hists,
            spans: build_tree(&records, mode),
            dropped_spans: dropped,
        }
    }
}
