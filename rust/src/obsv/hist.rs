//! Fixed-bucket log-scale histograms: the crate's one percentile
//! implementation.
//!
//! 256 geometric buckets with ratio 2^(1/4) (≈19% bucket width) span
//! `[1e-9, ~1.8e10)` — nanoseconds to hours when the unit is seconds,
//! and any realistic batch-size/byte count when it is a plain count.
//! Observations are lock-free atomic increments; quantiles interpolate
//! linearly inside the bucket that contains the target rank, so they
//! match a sort-based oracle to within one bucket width (property-
//! tested below). `server::service::DurationStats` and the serve bench
//! both report through this type — there is no other p50/p95/p99 math
//! in the tree.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Number of geometric buckets (underflow clamps into bucket 0,
/// overflow into the last bucket).
pub const BUCKETS: usize = 256;

/// Lower edge of the geometric range; bucket 0 additionally absorbs
/// everything below it (zeros, subnormal latencies).
pub const BUCKET_LO: f64 = 1e-9;

/// Geometric bucket growth ratio, 2^(1/4).
pub const BUCKET_RATIO: f64 = 1.189_207_115_002_721_1;

/// Worst-case relative error of an interpolated quantile against the
/// sort-based oracle: one bucket width, `BUCKET_RATIO - 1`.
pub const RELATIVE_BUCKET_WIDTH: f64 = BUCKET_RATIO - 1.0;

/// What a histogram's values measure. Snapshots keep the unit, and the
/// deterministic snapshot mode drops `Seconds` histograms (measured
/// wall time can never replay bitwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    /// Wall-clock seconds (latencies, makespans).
    Seconds,
    /// Dimensionless counts (batch rows, queue depths, bytes).
    Count,
}

impl Unit {
    /// Stable lowercase name used in snapshots.
    pub fn name(self) -> &'static str {
        match self {
            Unit::Seconds => "seconds",
            Unit::Count => "count",
        }
    }
}

/// Lower edge of bucket `i` (0.0 for the underflow bucket).
pub fn bucket_lower(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        BUCKET_LO * BUCKET_RATIO.powi(i as i32)
    }
}

/// Upper edge of bucket `i`.
pub fn bucket_upper(i: usize) -> f64 {
    BUCKET_LO * BUCKET_RATIO.powi(i as i32 + 1)
}

fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= BUCKET_LO {
        return 0; // zeros, negatives, NaN, underflow
    }
    let idx = ((v / BUCKET_LO).log2() * 4.0).floor() as usize;
    idx.min(BUCKETS - 1)
}

fn cas_f64(cell: &AtomicU64, f: impl Fn(f64) -> f64) {
    let mut cur = cell.load(Relaxed);
    loop {
        let next = f(f64::from_bits(cur)).to_bits();
        if next == cur {
            return;
        }
        match cell.compare_exchange_weak(cur, next, Relaxed, Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

/// A lock-free log-scale histogram. Cheap enough to sit under serve
/// loops (one atomic increment + three CAS updates per observation, no
/// allocation); exact count/sum/min/max, interpolated quantiles.
pub struct Histogram {
    unit: Unit,
    counts: Vec<AtomicU64>,
    n: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Histogram {
    /// Empty histogram measuring `unit` values.
    pub fn new(unit: Unit) -> Histogram {
        Histogram {
            unit,
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            n: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }

    /// The unit this histogram measures.
    pub fn unit(&self) -> Unit {
        self.unit
    }

    /// Record one value.
    pub fn observe(&self, v: f64) {
        self.counts[bucket_of(v)].fetch_add(1, Relaxed);
        self.n.fetch_add(1, Relaxed);
        cas_f64(&self.sum_bits, |s| s + v);
        cas_f64(&self.min_bits, |m| m.min(v));
        cas_f64(&self.max_bits, |m| m.max(v));
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n.load(Relaxed)
    }

    /// Exact sum of observations.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Relaxed))
    }

    /// Exact minimum (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.count() == 0 {
            0.0
        } else {
            f64::from_bits(self.min_bits.load(Relaxed))
        }
    }

    /// Exact maximum (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.count() == 0 {
            0.0
        } else {
            f64::from_bits(self.max_bits.load(Relaxed))
        }
    }

    /// Interpolated quantile, `q` in `[0, 1]`. Uses the same
    /// nearest-rank target as a sort oracle (`ceil(q·n)`), then
    /// interpolates linearly inside the target bucket and clamps to
    /// the observed `[min, max]` — so the result differs from
    /// `sorted[ceil(q·n)-1]` by at most one bucket width
    /// ([`RELATIVE_BUCKET_WIDTH`]). Returns 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Relaxed);
            if c == 0 {
                continue;
            }
            if cum + c >= target {
                let lo = bucket_lower(i);
                let hi = bucket_upper(i);
                let frac = (target - cum) as f64 / c as f64;
                let est = lo + frac * (hi - lo);
                return est.clamp(self.min(), self.max());
            }
            cum += c;
        }
        self.max()
    }

    /// Non-empty buckets as `(index, count)` pairs, ascending index.
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let c = c.load(Relaxed);
                (c > 0).then_some((i, c))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::prop::{prop_check, Gen};

    /// Sort-based oracle with the same nearest-rank definition the
    /// histogram targets.
    fn oracle(samples: &[f64], q: f64) -> f64 {
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let target = ((q * s.len() as f64).ceil() as usize).clamp(1, s.len());
        s[target - 1]
    }

    /// The tentpole's histogram contract: interpolated quantiles match
    /// the sort oracle to within one bucket width, across magnitudes
    /// from sub-microsecond latencies to large counts.
    #[test]
    fn quantile_matches_sort_oracle_within_one_bucket() {
        prop_check("hist-vs-oracle", 40, |g: &mut Gen| {
            let n = g.usize_in(1, 400);
            let scale = 10f64.powi(g.usize_in(0, 9) as i32 - 7);
            let samples: Vec<f64> =
                (0..n).map(|_| g.f64_in(0.01, 100.0) * scale).collect();
            let h = Histogram::new(Unit::Seconds);
            for &v in &samples {
                h.observe(v);
            }
            for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                let want = oracle(&samples, q);
                let got = h.quantile(q);
                let tol = want.abs() * RELATIVE_BUCKET_WIDTH + BUCKET_LO;
                assert!(
                    (got - want).abs() <= tol,
                    "q={q}: hist {got} vs oracle {want} (tol {tol}, n={n})"
                );
            }
        });
    }

    #[test]
    fn exact_moments_and_edges() {
        let h = Histogram::new(Unit::Count);
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        for v in [3.0, 1.0, 2.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 6.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 3.0);
        // quantiles are clamped to the observed range
        assert!(h.quantile(1.0) <= 3.0);
        assert!(h.quantile(0.0) >= 1.0);
    }

    /// Underflow and overflow clamp into the end buckets instead of
    /// being dropped, and quantiles stay within the observed range.
    #[test]
    fn clamps_out_of_range_values() {
        let h = Histogram::new(Unit::Count);
        h.observe(0.0);
        h.observe(-5.0);
        h.observe(1e300);
        assert_eq!(h.count(), 3);
        let b = h.nonzero_buckets();
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].0, 0);
        assert_eq!(b[0].1, 2);
        assert_eq!(b[1].0, BUCKETS - 1);
        assert!(h.quantile(1.0) <= 1e300);
    }

    #[test]
    fn bucket_edges_are_geometric() {
        assert_eq!(bucket_lower(0), 0.0);
        for i in 1..BUCKETS {
            let w = bucket_upper(i) / bucket_lower(i);
            assert!((w - BUCKET_RATIO).abs() < 1e-12);
        }
    }
}
