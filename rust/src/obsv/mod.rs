//! Repo-wide telemetry: a metrics registry, phase-span tracing, and
//! exporters — the observability layer ROADMAP item 2 needs before
//! anything listens on a socket.
//!
//! Three pillars:
//!
//! 1. **Metrics registry** ([`Registry`]): process-global, lock-light
//!    named counters, gauges, and log-scale latency [`Histogram`]s
//!    (one percentile implementation for the whole tree — see
//!    [`hist`]). Record calls are free functions ([`counter_add`],
//!    [`gauge_add`], [`observe`]) resolving metrics by name: atomics
//!    on the hot path, no allocation per observation.
//! 2. **Phase-span tracing** ([`span`], [`SpanGuard`]): RAII guards
//!    that nest (fit → protocol phase → collective), feeding a bounded
//!    ring-buffer journal with monotonic timestamps and structured
//!    fields. The cluster simulator, the three parallel protocols,
//!    distributed training, the serve loop, and the linalg pool
//!    dispatch all record here.
//! 3. **Exporters** ([`TelemetrySnapshot`]): deterministic JSON
//!    (stable key order, test-pinnable) and Prometheus text, surfaced
//!    as `pgpr stats` and the `--telemetry-out` flags.
//!
//! ## Enablement
//!
//! Telemetry is on by default; `PGPR_TELEMETRY=0` disables it, making
//! every record call a branch on one relaxed atomic load (the
//! disabled-mode overhead rides inside linalg_bench's pooled-vs-serial
//! ≤1.10× gate, which measures kernels with the record sites inlined).
//! [`set_enabled`] is the programmatic override.
//!
//! ## Scoped registries (tests)
//!
//! `cargo test` runs threads concurrently, so assertions against the
//! process-global registry would race. [`Registry::install`] pushes a
//! fresh registry as the *calling thread's* recorder (RAII guard);
//! with the serial cluster executor every record lands there, giving
//! deterministic, isolated telemetry — the chaos snapshot pin in
//! `tests/integration_faults.rs` replays a faulted run twice into two
//! scoped registries and asserts bitwise-equal JSON.

pub mod hist;
pub mod snapshot;
pub mod span;

pub use hist::{Histogram, Unit, RELATIVE_BUCKET_WIDTH};
pub use snapshot::{HistSnapshot, SnapshotMode, SpanNode, TelemetrySnapshot};
pub use span::{FieldValue, Parent, SpanGuard};

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{
    AtomicI64, AtomicU64, AtomicU8, AtomicUsize, Ordering::Relaxed,
};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::Instant;

const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

/// The single hot-path gate: ON iff the global flag is on or any
/// scoped registry is installed anywhere in the process.
static ACTIVE: AtomicU8 = AtomicU8::new(UNINIT);
static GLOBAL_ON: AtomicU8 = AtomicU8::new(UNINIT);
static SCOPES: AtomicUsize = AtomicUsize::new(0);

/// Whether any recording can happen: one relaxed load in the steady
/// state (the disabled-mode contract every record site branches on).
#[inline]
pub fn enabled() -> bool {
    match ACTIVE.load(Relaxed) {
        ON => true,
        OFF => false,
        _ => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> bool {
    recompute_active()
}

/// Resolve `GLOBAL_ON` from `PGPR_TELEMETRY` exactly once (default
/// on; `0` disables). Needed by every recompute, not just the first
/// `enabled()` call: a scope guard dropping before any record call
/// must not freeze `ACTIVE` to OFF with the env never consulted.
fn ensure_global_init() {
    if GLOBAL_ON.load(Relaxed) == UNINIT {
        let on =
            !matches!(std::env::var("PGPR_TELEMETRY").as_deref(), Ok("0"));
        let _ = GLOBAL_ON.compare_exchange(
            UNINIT,
            if on { ON } else { OFF },
            Relaxed,
            Relaxed,
        );
    }
}

fn recompute_active() -> bool {
    ensure_global_init();
    let a = GLOBAL_ON.load(Relaxed) == ON || SCOPES.load(Relaxed) > 0;
    ACTIVE.store(if a { ON } else { OFF }, Relaxed);
    a
}

/// Programmatic override of the `PGPR_TELEMETRY` gate (benches use it
/// to honor `--telemetry-out` regardless of the environment).
pub fn set_enabled(on: bool) {
    GLOBAL_ON.store(if on { ON } else { OFF }, Relaxed);
    recompute_active();
}

/// The telemetry registry: named metrics plus the span journal.
///
/// One process-global instance backs normal operation ([`global`]);
/// tests install fresh instances per thread via [`Registry::install`].
pub struct Registry {
    epoch: Instant,
    counters: RwLock<HashMap<String, AtomicU64>>,
    gauges: RwLock<HashMap<String, AtomicI64>>,
    hists: RwLock<HashMap<String, Histogram>>,
    journal: span::Journal,
    span_seq: AtomicU64,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    /// Fresh empty registry with its own monotonic epoch.
    pub fn new() -> Registry {
        Registry {
            epoch: Instant::now(),
            counters: RwLock::new(HashMap::new()),
            gauges: RwLock::new(HashMap::new()),
            hists: RwLock::new(HashMap::new()),
            journal: span::Journal::new(),
            span_seq: AtomicU64::new(0),
        }
    }

    /// Install `self` as the calling thread's recorder until the guard
    /// drops. Forces recording on for this thread even when
    /// `PGPR_TELEMETRY=0` — the isolation mechanism every telemetry
    /// test uses.
    pub fn install(self: &Arc<Registry>) -> ScopeGuard {
        SCOPE.with(|s| s.borrow_mut().push(self.clone()));
        SCOPES.fetch_add(1, Relaxed);
        recompute_active();
        ScopeGuard {
            _not_send: std::marker::PhantomData,
        }
    }

    /// Monotonic nanoseconds since this registry's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    pub(crate) fn next_span_id(&self) -> u64 {
        self.span_seq.fetch_add(1, Relaxed) + 1
    }

    pub(crate) fn journal(&self) -> &span::Journal {
        &self.journal
    }

    /// Add to a named counter.
    pub fn counter_add(&self, name: &str, v: u64) {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            c.fetch_add(v, Relaxed);
            return;
        }
        self.counters
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(v, Relaxed);
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter_get(&self, name: &str) -> u64 {
        self.counters
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Relaxed))
            .unwrap_or(0)
    }

    /// Add a (possibly negative) delta to a named gauge.
    pub fn gauge_add(&self, name: &str, delta: i64) {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            g.fetch_add(delta, Relaxed);
            return;
        }
        self.gauges
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| AtomicI64::new(0))
            .fetch_add(delta, Relaxed);
    }

    /// Set a named gauge.
    pub fn gauge_set(&self, name: &str, v: i64) {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            g.store(v, Relaxed);
            return;
        }
        self.gauges
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| AtomicI64::new(0))
            .store(v, Relaxed);
    }

    /// Current value of a gauge (0 if never touched).
    pub fn gauge_get(&self, name: &str) -> i64 {
        self.gauges
            .read()
            .unwrap()
            .get(name)
            .map(|g| g.load(Relaxed))
            .unwrap_or(0)
    }

    /// Record a value into a named histogram (created with `unit` on
    /// first use).
    pub fn observe(&self, name: &str, unit: Unit, v: f64) {
        if let Some(h) = self.hists.read().unwrap().get(name) {
            h.observe(v);
            return;
        }
        self.hists
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(unit))
            .observe(v);
    }

    /// Interpolated quantile of a named histogram, `None` if absent.
    pub fn hist_quantile(&self, name: &str, q: f64) -> Option<f64> {
        self.hists.read().unwrap().get(name).map(|h| h.quantile(q))
    }

    pub(crate) fn counters_view<R>(
        &self,
        f: impl FnOnce(&HashMap<String, AtomicU64>) -> R,
    ) -> R {
        f(&self.counters.read().unwrap())
    }

    pub(crate) fn gauges_view<R>(
        &self,
        f: impl FnOnce(&HashMap<String, AtomicI64>) -> R,
    ) -> R {
        f(&self.gauges.read().unwrap())
    }

    pub(crate) fn hists_view<R>(
        &self,
        f: impl FnOnce(&HashMap<String, Histogram>) -> R,
    ) -> R {
        f(&self.hists.read().unwrap())
    }
}

/// RAII guard from [`Registry::install`]; restores the previous
/// recorder on drop. Deliberately `!Send`.
pub struct ScopeGuard {
    _not_send: std::marker::PhantomData<*const ()>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        SCOPE.with(|s| {
            s.borrow_mut().pop();
        });
        SCOPES.fetch_sub(1, Relaxed);
        recompute_active();
    }
}

thread_local! {
    static SCOPE: RefCell<Vec<Arc<Registry>>> = const { RefCell::new(Vec::new()) };
    static LABEL_SCRATCH: RefCell<String> = const { RefCell::new(String::new()) };
}

static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();

/// The process-global registry (created on first use).
pub fn global() -> &'static Arc<Registry> {
    GLOBAL.get_or_init(|| Arc::new(Registry::new()))
}

/// The registry that should receive a record from this thread:
/// innermost scoped registry first, else the global one when the
/// `PGPR_TELEMETRY` gate is on, else `None`.
pub(crate) fn recorder_arc() -> Option<Arc<Registry>> {
    if !enabled() {
        return None;
    }
    if let Some(r) = SCOPE.with(|s| s.borrow().last().cloned()) {
        return Some(r);
    }
    if GLOBAL_ON.load(Relaxed) == ON {
        return Some(global().clone());
    }
    None
}

fn with_recorder<R>(f: impl FnOnce(&Registry) -> R) -> Option<R> {
    if !enabled() {
        return None;
    }
    let scoped = SCOPE.with(|s| s.borrow().last().cloned());
    match scoped {
        Some(r) => Some(f(&r)),
        None if GLOBAL_ON.load(Relaxed) == ON => Some(f(global())),
        None => None,
    }
}

/// Add to a named counter on the active recorder (no-op when off).
#[inline]
pub fn counter_add(name: &str, v: u64) {
    if !enabled() {
        return;
    }
    let _ = with_recorder(|r| r.counter_add(name, v));
}

/// Add to the counter `"{name}.{label}"` — method-labeled request
/// counters compose the key in a thread-local scratch buffer, so the
/// steady state allocates nothing.
#[inline]
pub fn counter_add_labeled(name: &str, label: &str, v: u64) {
    if !enabled() {
        return;
    }
    LABEL_SCRATCH.with(|k| {
        let mut k = k.borrow_mut();
        k.clear();
        k.push_str(name);
        k.push('.');
        k.push_str(label);
        let _ = with_recorder(|r| r.counter_add(&k, v));
    });
}

/// Add a delta to a named gauge on the active recorder.
#[inline]
pub fn gauge_add(name: &str, delta: i64) {
    if !enabled() {
        return;
    }
    let _ = with_recorder(|r| r.gauge_add(name, delta));
}

/// Set a named gauge on the active recorder.
#[inline]
pub fn gauge_set(name: &str, v: i64) {
    if !enabled() {
        return;
    }
    let _ = with_recorder(|r| r.gauge_set(name, v));
}

/// Record into a named histogram on the active recorder.
#[inline]
pub fn observe(name: &str, unit: Unit, v: f64) {
    if !enabled() {
        return;
    }
    let _ = with_recorder(|r| r.observe(name, unit, v));
}

/// Open an RAII span named `name` (no-op shell when off). Fields
/// attach builder-style: `obsv::span("protocol.pPITC").with_u64("machines", m)`.
pub fn span(name: &'static str) -> SpanGuard {
    match recorder_arc() {
        Some(reg) => SpanGuard::open(reg, name),
        None => SpanGuard::disabled(),
    }
}

/// Monotonic nanoseconds since the active recorder's epoch (0 when
/// off). Pairs with [`emit_span_at`] for non-RAII-shaped spans.
pub fn now_ns() -> u64 {
    with_recorder(|r| r.now_ns()).unwrap_or(0)
}

/// Record an already-completed span with explicit times and parent;
/// returns its id (0 when off) for use as [`Parent::Explicit`] by
/// later events — how `Cluster::phase` nests the collective events
/// that happened inside the phase it is sealing.
pub fn emit_span_at(
    name: &str,
    start_ns: u64,
    end_ns: u64,
    parent: Parent,
    fields: Vec<(&'static str, FieldValue)>,
) -> u64 {
    match recorder_arc() {
        None => 0,
        Some(reg) => {
            let id = reg.next_span_id();
            let parent = match parent {
                Parent::Current => span::current_parent(),
                Parent::Explicit(p) => p,
                Parent::Root => 0,
            };
            reg.journal().push(span::SpanRecord {
                id,
                parent,
                name: name.to_string(),
                start_ns,
                end_ns,
                fields,
            });
            id
        }
    }
}

/// Snapshot the active recorder (empty snapshot when off).
pub fn snapshot(mode: SnapshotMode) -> TelemetrySnapshot {
    match recorder_arc() {
        Some(r) => r.snapshot(mode),
        None => TelemetrySnapshot::empty(mode),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Counters, gauges, hists, and spans land in a scoped registry
    /// and render to stable-key-order JSON.
    #[test]
    fn scoped_registry_records_and_snapshots() {
        let reg = Arc::new(Registry::new());
        let _g = reg.install();
        counter_add("test.counter", 2);
        counter_add("test.counter", 3);
        counter_add_labeled("test.requests", "pPITC", 4);
        gauge_add("test.depth", 5);
        gauge_add("test.depth", -2);
        observe("test.rows", Unit::Count, 8.0);
        {
            let _outer = span("outer").with_u64("m", 4);
            let _inner = span("inner");
        }
        let snap = reg.snapshot(SnapshotMode::Full);
        assert_eq!(snap.counters["test.counter"], 5);
        assert_eq!(snap.counters["test.requests.pPITC"], 4);
        assert_eq!(snap.gauges["test.depth"], 3);
        assert_eq!(snap.hists["test.rows"].count, 1);
        assert_eq!(snap.spans.len(), 1);
        assert_eq!(snap.spans[0].name, "outer");
        assert_eq!(snap.spans[0].children.len(), 1);
        assert_eq!(snap.spans[0].children[0].name, "inner");
        let js = snap.to_json().to_string_compact();
        assert!(js.contains("\"pgpr-telemetry/1\""));
        let parsed = crate::util::json::Json::parse(&js).unwrap();
        assert!(parsed.get("counters").is_some());
        let prom = snap.to_prometheus();
        assert!(prom.contains("pgpr_test_counter 5"));
        assert!(prom.contains("# TYPE pgpr_test_depth gauge"));
        assert!(prom.contains("pgpr_test_rows_count 1"));
    }

    /// Deterministic mode drops measured-time content: seconds-unit
    /// histograms, span timestamps, and F64 fields.
    #[test]
    fn deterministic_mode_drops_measured_time() {
        let reg = Arc::new(Registry::new());
        let _g = reg.install();
        observe("t.lat", Unit::Seconds, 0.25);
        observe("t.rows", Unit::Count, 3.0);
        {
            let _s = span("p").with_u64("bytes", 7).with_f64("secs", 0.5);
        }
        let det = reg.snapshot(SnapshotMode::Deterministic);
        assert!(!det.hists.contains_key("t.lat"));
        assert!(det.hists.contains_key("t.rows"));
        assert_eq!(det.spans.len(), 1);
        assert!(det.spans[0].start_ns.is_none());
        assert!(det.spans[0].dur_ns.is_none());
        let keys: Vec<&str> =
            det.spans[0].fields.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["bytes"]);
        let full = reg.snapshot(SnapshotMode::Full);
        assert!(full.hists.contains_key("t.lat"));
        assert!(full.spans[0].dur_ns.is_some());
    }

    /// `emit_span_at` re-parents: events emitted after their synthetic
    /// parent nest under it (the `Cluster::phase` shape).
    #[test]
    fn explicit_parent_nests_events() {
        let reg = Arc::new(Registry::new());
        let _g = reg.install();
        let _outer = span("protocol");
        let t0 = now_ns();
        let pid = emit_span_at("phase.x", t0, now_ns(), Parent::Current, vec![]);
        emit_span_at(
            "collective.reduce",
            t0,
            t0,
            Parent::Explicit(pid),
            vec![("bytes", FieldValue::U64(64))],
        );
        drop(_outer);
        let snap = reg.snapshot(SnapshotMode::Deterministic);
        assert_eq!(snap.spans.len(), 1);
        let proto = &snap.spans[0];
        assert_eq!(proto.name, "protocol");
        assert_eq!(proto.children.len(), 1);
        let phase = &proto.children[0];
        assert_eq!(phase.name, "phase.x");
        assert_eq!(phase.children[0].name, "collective.reduce");
    }

    /// The scope guard restores the previous recorder, and nested
    /// scopes shadow outer ones.
    #[test]
    fn scopes_nest_and_restore() {
        let a = Arc::new(Registry::new());
        let b = Arc::new(Registry::new());
        let _ga = a.install();
        counter_add("n.c", 1);
        {
            let _gb = b.install();
            counter_add("n.c", 10);
        }
        counter_add("n.c", 1);
        assert_eq!(a.counter_get("n.c"), 2);
        assert_eq!(b.counter_get("n.c"), 10);
    }
}
