//! The simulated cluster with MPI-style collectives.
//!
//! Execution model: real work runs on the host — serially, or truly in
//! parallel on a [`ParallelExecutor`] thread pool — and each node's own
//! measured wall time advances that node's virtual clock;
//! communication advances clocks per [`NetworkModel`] with binomial-tree
//! collectives. Node 0 is the master (footnote 1 of the paper: "one of
//! the M machines can be assigned to be the master"). The run's real
//! host wall-clock is recorded separately in [`RunMetrics::wall_s`], so
//! reports carry both the modeled makespan and the realized time.

use super::exec::ParallelExecutor;
use super::metrics::{Phase, RunMetrics};
use super::network::NetworkModel;
use super::node::Node;
use crate::util::Stopwatch;

/// A simulated M-node cluster.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    pub net: NetworkModel,
    exec: ParallelExecutor,
    wall: Stopwatch,
    metrics: RunMetrics,
}

pub const MASTER: usize = 0;

impl Cluster {
    /// Serial-execution cluster (the seed behavior).
    pub fn new(m: usize, net: NetworkModel) -> Cluster {
        Cluster::with_exec(m, net, ParallelExecutor::serial())
    }

    /// Cluster whose per-node work runs on `exec` (thread-parallel when
    /// the executor carries a pool).
    pub fn with_exec(m: usize, net: NetworkModel, exec: ParallelExecutor)
        -> Cluster
    {
        assert!(m >= 1, "cluster needs at least one node");
        Cluster {
            nodes: (0..m).map(Node::new).collect(),
            net,
            exec,
            wall: Stopwatch::new(),
            metrics: RunMetrics::default(),
        }
    }

    pub fn size(&self) -> usize {
        self.nodes.len()
    }

    /// Current makespan (max node clock).
    pub fn makespan(&self) -> f64 {
        self.nodes.iter().map(|n| n.clock()).fold(0.0, f64::max)
    }

    /// Run `work` as node `id`'s local compute; measured wall time
    /// advances that node's clock.
    pub fn compute_on<T>(&mut self, id: usize, work: impl FnOnce() -> T) -> T {
        let (out, secs) = Stopwatch::time(work);
        self.nodes[id].advance_compute(secs);
        out
    }

    /// Run `work(m)` for every node m — concurrently on the executor's
    /// thread pool when one is configured, serially otherwise. Either
    /// way each node's clock advances by its own measured time only, and
    /// results come back in node order, so the two modes are numerically
    /// identical (the paper's Theorems 1–2 oracle; asserted in
    /// `tests/integration_parallel_exec.rs`).
    pub fn compute_all<T: Send>(
        &mut self,
        work: impl Fn(usize) -> T + Sync,
    ) -> Vec<T> {
        let timed = self.exec.run_timed(self.size(), work);
        timed
            .into_iter()
            .enumerate()
            .map(|(id, (out, secs))| {
                self.nodes[id].advance_compute(secs);
                out
            })
            .collect()
    }

    /// Like [`Cluster::compute_all`] but always executed inline,
    /// whatever the configured executor — for per-iteration
    /// microsecond-scale scans (e.g. the pICF pivot candidates, issued
    /// `rank` times) where pool dispatch would dominate the work itself.
    /// Clock semantics and results are identical to `compute_all`.
    pub fn compute_all_inline<T>(
        &mut self,
        mut work: impl FnMut(usize) -> T,
    ) -> Vec<T> {
        (0..self.size())
            .map(|id| {
                let (out, secs) = Stopwatch::time(|| work(id));
                self.nodes[id].advance_compute(secs);
                out
            })
            .collect()
    }

    /// Charge node `id` a fixed amount of *modeled* compute seconds (used
    /// when the per-node work is too fine-grained to measure reliably).
    pub fn charge_compute(&mut self, id: usize, secs: f64) {
        self.nodes[id].advance_compute(secs);
    }

    /// Synchronize all clocks at the current makespan (barrier).
    pub fn barrier(&mut self) {
        let t = self.makespan();
        for n in self.nodes.iter_mut() {
            n.wait_until(t);
        }
    }

    /// Reduce `bytes`-sized values from all nodes to the master along a
    /// binomial tree: ceil(log2 M) rounds. Master ends at
    /// max(all clocks) + rounds·transfer(bytes).
    pub fn reduce_to_master(&mut self, bytes: usize) {
        let m = self.size();
        if m <= 1 {
            return;
        }
        let t_done = self.makespan() + self.net.collective_time(m, bytes);
        self.nodes[MASTER].wait_until(t_done);
        self.metrics.bytes_sent += bytes * (m - 1);
        self.metrics.messages += m - 1;
    }

    /// Broadcast `bytes` from the master to all nodes (binomial tree).
    /// Every node ends at master_clock + rounds·transfer(bytes).
    pub fn bcast_from_master(&mut self, bytes: usize) {
        let m = self.size();
        if m <= 1 {
            return;
        }
        let t_done =
            self.nodes[MASTER].clock() + self.net.collective_time(m, bytes);
        for n in self.nodes.iter_mut() {
            n.wait_until(t_done);
        }
        self.metrics.bytes_sent += bytes * (m - 1);
        self.metrics.messages += m - 1;
    }

    /// Gather `bytes` from every node to the master: latency amortized
    /// over a tree (log M rounds) but the master still receives all the
    /// payload: rounds·latency + (M−1)·bytes/bandwidth.
    pub fn gather_to_master(&mut self, bytes: usize) {
        let m = self.size();
        if m <= 1 {
            return;
        }
        let rounds = NetworkModel::tree_rounds(m) as f64;
        let t = rounds * self.net.latency_s
            + ((m - 1) * bytes) as f64 * 8.0
                / self.net.bandwidth_bps.max(f64::MIN_POSITIVE);
        let t_done = self.makespan() + t;
        self.nodes[MASTER].wait_until(t_done);
        self.metrics.bytes_sent += bytes * (m - 1);
        self.metrics.messages += m - 1;
    }

    /// Allreduce of `bytes` across all nodes (butterfly/recursive
    /// doubling): log M rounds, everyone ends synchronized at
    /// max(clocks) + rounds·transfer (the MPI_Allreduce/MAXLOC shape the
    /// row-based parallel ICF uses each iteration).
    pub fn allreduce(&mut self, bytes: usize) {
        let m = self.size();
        if m <= 1 {
            return;
        }
        let t_done = self.makespan() + self.net.collective_time(m, bytes);
        for n in self.nodes.iter_mut() {
            n.wait_until(t_done);
        }
        // butterfly: every node sends one message per round
        let rounds = NetworkModel::tree_rounds(m);
        self.metrics.bytes_sent += bytes * m * rounds / 2;
        self.metrics.messages += m * rounds / 2;
    }

    /// All-to-all personalized exchange of `bytes` per pair (the pPIC
    /// clustering shuffle): each node sends M−1 messages.
    pub fn alltoall(&mut self, bytes_per_pair: usize) {
        let m = self.size();
        if m <= 1 {
            return;
        }
        let per_node = (m - 1) as f64 * self.net.transfer_time(bytes_per_pair);
        let t_done = self.makespan() + per_node;
        for n in self.nodes.iter_mut() {
            n.wait_until(t_done);
        }
        self.metrics.bytes_sent += bytes_per_pair * m * (m - 1);
        self.metrics.messages += m * (m - 1);
    }

    /// Mark the end of a named protocol phase.
    pub fn phase(&mut self, name: &str) {
        self.metrics.phases.push(Phase {
            name: name.to_string(),
            end_makespan: self.makespan(),
        });
    }

    /// Finish the run and take the metrics.
    pub fn finish(mut self) -> RunMetrics {
        self.metrics.makespan = self.makespan();
        self.metrics.total_compute =
            self.nodes.iter().map(|n| n.compute_total()).sum();
        self.metrics.max_compute = self
            .nodes
            .iter()
            .map(|n| n.compute_total())
            .fold(0.0, f64::max);
        self.metrics.wall_s = self.wall.elapsed();
        self.metrics.threads = self.exec.workers();
        self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;
    use std::time::Duration;

    fn fast_net() -> NetworkModel {
        NetworkModel { latency_s: 1e-3, bandwidth_bps: 1e9 }
    }

    #[test]
    fn compute_all_advances_individual_clocks() {
        let mut c = Cluster::new(3, NetworkModel::instant());
        c.compute_all(|id| sleep(Duration::from_millis(2 * (id as u64 + 1))));
        // node 2 slept longest
        assert!(c.nodes[2].clock() > c.nodes[0].clock());
        // makespan is max clock, NOT the sum (that's the parallelism)
        let sum: f64 = c.nodes.iter().map(|n| n.clock()).sum();
        assert!(c.makespan() < sum);
    }

    #[test]
    fn reduce_only_advances_master_beyond_max() {
        let mut c = Cluster::new(4, fast_net());
        c.charge_compute(2, 0.5); // slowest worker
        c.reduce_to_master(1000);
        // master waited for node 2 plus 2 rounds of ~1ms
        assert!(c.nodes[MASTER].clock() >= 0.5 + 2.0 * 1e-3);
        // other workers unaffected
        assert_eq!(c.nodes[1].clock(), 0.0);
    }

    #[test]
    fn bcast_synchronizes_to_master_time() {
        let mut c = Cluster::new(4, fast_net());
        c.charge_compute(MASTER, 1.0);
        c.bcast_from_master(100);
        for n in &c.nodes {
            assert!(n.clock() >= 1.0);
        }
    }

    #[test]
    fn gather_cost_scales_with_payload() {
        let mut a = Cluster::new(8, fast_net());
        a.gather_to_master(1_000_000);
        let mut b = Cluster::new(8, fast_net());
        b.gather_to_master(10_000_000);
        assert!(b.nodes[MASTER].clock() > a.nodes[MASTER].clock());
    }

    #[test]
    fn traffic_accounting() {
        let mut c = Cluster::new(5, fast_net());
        c.reduce_to_master(10);
        c.bcast_from_master(20);
        let m = c.finish();
        assert_eq!(m.bytes_sent, 10 * 4 + 20 * 4);
        assert_eq!(m.messages, 8);
    }

    #[test]
    fn single_node_communication_free() {
        let mut c = Cluster::new(1, fast_net());
        c.reduce_to_master(1000);
        c.bcast_from_master(1000);
        c.alltoall(1000);
        let m = c.finish();
        assert_eq!(m.bytes_sent, 0);
        assert_eq!(m.makespan, 0.0);
    }

    #[test]
    fn phases_and_finish() {
        let mut c = Cluster::new(2, NetworkModel::instant());
        c.charge_compute(0, 1.0);
        c.phase("one");
        c.charge_compute(1, 3.0);
        c.phase("two");
        let m = c.finish();
        assert_eq!(m.phases.len(), 2);
        assert_eq!(m.phase_duration(0), 1.0);
        assert_eq!(m.phase_duration(1), 2.0); // makespan 1 -> 3
        assert_eq!(m.makespan, 3.0);
        assert_eq!(m.total_compute, 4.0);
        assert_eq!(m.max_compute, 3.0);
    }

    #[test]
    fn parallel_compute_all_matches_serial_and_advances_clocks() {
        let work = |id: usize| -> f64 {
            // deterministic per-node numeric work
            (0..2000).map(|k| ((id + 1) * (k + 1)) as f64).sum::<f64>().sqrt()
        };
        let mut serial = Cluster::new(4, NetworkModel::instant());
        let a = serial.compute_all(work);
        let mut par = Cluster::with_exec(4, NetworkModel::instant(),
                                         ParallelExecutor::threads(4));
        let b = par.compute_all(work);
        assert_eq!(a, b, "thread-parallel results must be identical");
        for n in &par.nodes {
            assert!(n.clock() > 0.0, "node {} clock did not advance", n.id);
        }
        let m = par.finish();
        assert_eq!(m.threads, 4);
        assert!(m.wall_s > 0.0);
    }

    #[test]
    fn finish_records_serial_executor() {
        let mut c = Cluster::new(2, NetworkModel::instant());
        c.charge_compute(0, 0.1);
        let m = c.finish();
        assert_eq!(m.threads, 1);
        assert!(m.wall_s >= 0.0);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let mut c = Cluster::new(3, NetworkModel::instant());
        c.charge_compute(1, 2.0);
        c.barrier();
        for n in &c.nodes {
            assert_eq!(n.clock(), 2.0);
        }
    }
}
