//! The simulated cluster with MPI-style collectives.
//!
//! Execution model: real work runs on the host — serially, or truly in
//! parallel on a [`ParallelExecutor`] thread pool — and each node's own
//! measured wall time advances that node's virtual clock;
//! communication advances clocks per [`NetworkModel`] with binomial-tree
//! collectives. Node 0 is the master (footnote 1 of the paper: "one of
//! the M machines can be assigned to be the master"). The run's real
//! host wall-clock is recorded separately in [`RunMetrics::wall_s`], so
//! reports carry both the modeled makespan and the realized time.

use super::exec::ParallelExecutor;
use super::fault::FaultCounters;
use super::metrics::{Phase, RunMetrics};
use super::network::NetworkModel;
use super::node::Node;
use super::transport::{DirectTransport, ExchangeOutcome, Transport};
use crate::util::Stopwatch;

/// A simulated M-node cluster.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    pub net: NetworkModel,
    exec: ParallelExecutor,
    wall: Stopwatch,
    metrics: RunMetrics,
    /// Mediates every exchange; [`DirectTransport`] is the failure-free
    /// default and collectives degenerate to their historical behavior.
    transport: Box<dyn Transport>,
    /// Fault-counter snapshot at the last phase boundary.
    phase_mark: FaultCounters,
    /// Telemetry: collective events `(kind, bytes, at_ns)` since the
    /// last phase mark, re-parented under the phase span it seals
    /// (empty while telemetry is off).
    obsv_events: Vec<(&'static str, u64, u64)>,
    /// Telemetry: monotonic start of the currently-open phase.
    phase_start_ns: u64,
}

pub const MASTER: usize = 0;

impl Cluster {
    /// Serial-execution cluster (the seed behavior).
    pub fn new(m: usize, net: NetworkModel) -> Cluster {
        Cluster::with_exec(m, net, ParallelExecutor::serial())
    }

    /// Cluster whose per-node work runs on `exec` (thread-parallel when
    /// the executor carries a pool).
    pub fn with_exec(m: usize, net: NetworkModel, exec: ParallelExecutor)
        -> Cluster
    {
        Cluster::with_transport(m, net, exec, Box::new(DirectTransport))
    }

    /// Cluster whose exchanges are mediated by an explicit transport
    /// (the fault-injection entry point).
    pub fn with_transport(
        m: usize,
        net: NetworkModel,
        exec: ParallelExecutor,
        transport: Box<dyn Transport>,
    ) -> Cluster {
        assert!(m >= 1, "cluster needs at least one node");
        Cluster {
            nodes: (0..m).map(Node::new).collect(),
            net,
            exec,
            wall: Stopwatch::new(),
            metrics: RunMetrics::default(),
            transport,
            phase_mark: FaultCounters::default(),
            obsv_events: Vec::new(),
            phase_start_ns: crate::obsv::now_ns(),
        }
    }

    pub fn size(&self) -> usize {
        self.nodes.len()
    }

    /// Current makespan (max node clock).
    pub fn makespan(&self) -> f64 {
        self.nodes.iter().map(|n| n.clock()).fold(0.0, f64::max)
    }

    /// Ids of the machines still alive, ascending.
    pub fn alive_ids(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|n| n.alive())
            .map(|n| n.id)
            .collect()
    }

    pub fn alive_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.alive()).count()
    }

    pub fn is_alive(&self, id: usize) -> bool {
        self.nodes[id].alive()
    }

    /// Current master: the lowest-index alive machine (re-election on
    /// master death, footnote 1 of the paper generalized). Falls back
    /// to node 0 when everyone is dead.
    pub fn master(&self) -> usize {
        self.nodes
            .iter()
            .find(|n| n.alive())
            .map(|n| n.id)
            .unwrap_or(MASTER)
    }

    /// Max clock over alive machines (what collectives synchronize on;
    /// equals [`Cluster::makespan`] while everyone is alive).
    fn alive_max_clock(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| n.alive())
            .map(|n| n.clock())
            .fold(0.0, f64::max)
    }

    /// Declare machine `id` dead (frozen clock, out of all future
    /// collectives) and count the death.
    pub fn mark_dead(&mut self, id: usize) {
        if self.nodes[id].alive() {
            self.nodes[id].kill();
            self.metrics.faults.deaths += 1;
        }
    }

    /// Drain the transport's scheduled deaths for `phase`, apply them,
    /// and return the newly-dead ids (ascending).
    pub fn take_deaths(&mut self, phase: &str) -> Vec<usize> {
        let scheduled = self.transport.take_deaths(phase);
        let mut out = Vec::new();
        for id in scheduled {
            if id < self.nodes.len() && self.nodes[id].alive() {
                self.mark_dead(id);
                out.push(id);
            }
        }
        out
    }

    /// Telemetry: buffer one collective event (kind, total bytes moved)
    /// for re-parenting under the span of the phase that seals it. One
    /// branch on a relaxed load when telemetry is off.
    fn note_collective(&mut self, kind: &'static str, bytes: usize) {
        if crate::obsv::enabled() {
            self.obsv_events
                .push((kind, bytes as u64, crate::obsv::now_ns()));
        }
    }

    /// Apply one [`ExchangeOutcome`]: straggler delays move the
    /// affected clocks, retry/timeout counters accumulate, and
    /// retry-exhausted participants are marked dead. Returns the extra
    /// collective delay and the newly-dead ids.
    fn apply_exchange(&mut self, out: ExchangeOutcome) -> (f64, Vec<usize>) {
        for &(id, delay) in &out.straggles {
            if self.nodes[id].alive() {
                let t = self.nodes[id].clock() + delay;
                self.nodes[id].wait_until(t);
                self.metrics.faults.straggle_events += 1;
                self.metrics.faults.straggle_s += delay;
            }
        }
        self.metrics.faults.retries += out.retries;
        self.metrics.faults.timeouts += out.timeouts;
        let mut failed = Vec::new();
        for id in out.failed {
            if self.nodes[id].alive() {
                self.mark_dead(id);
                failed.push(id);
            }
        }
        (out.extra_delay_s, failed)
    }

    /// Run `work` as node `id`'s local compute; measured wall time
    /// advances that node's clock.
    pub fn compute_on<T>(&mut self, id: usize, work: impl FnOnce() -> T) -> T {
        let (out, secs) = Stopwatch::time(work);
        self.nodes[id].advance_compute(secs);
        out
    }

    /// Run `work(m)` for every node m — concurrently on the executor's
    /// thread pool when one is configured, serially otherwise. Either
    /// way each node's clock advances by its own measured time only, and
    /// results come back in node order, so the two modes are numerically
    /// identical (the paper's Theorems 1–2 oracle; asserted in
    /// `tests/integration_parallel_exec.rs`).
    pub fn compute_all<T: Send>(
        &mut self,
        work: impl Fn(usize) -> T + Sync,
    ) -> Vec<T> {
        let timed = self.exec.run_timed(self.size(), work);
        timed
            .into_iter()
            .enumerate()
            .map(|(id, (out, secs))| {
                self.nodes[id].advance_compute(secs);
                out
            })
            .collect()
    }

    /// Like [`Cluster::compute_all`] but always executed inline,
    /// whatever the configured executor — for per-iteration
    /// microsecond-scale scans (e.g. the pICF pivot candidates, issued
    /// `rank` times) where pool dispatch would dominate the work itself.
    /// Clock semantics and results are identical to `compute_all`.
    pub fn compute_all_inline<T>(
        &mut self,
        mut work: impl FnMut(usize) -> T,
    ) -> Vec<T> {
        (0..self.size())
            .map(|id| {
                let (out, secs) = Stopwatch::time(|| work(id));
                self.nodes[id].advance_compute(secs);
                out
            })
            .collect()
    }

    /// Charge node `id` a fixed amount of *modeled* compute seconds (used
    /// when the per-node work is too fine-grained to measure reliably).
    pub fn charge_compute(&mut self, id: usize, secs: f64) {
        self.nodes[id].advance_compute(secs);
    }

    /// Fault-aware [`Cluster::compute_all`]: run `work(m)` for every
    /// *alive* node m, returning `Some(result)` at alive indices and
    /// `None` at dead ones. With every machine alive this is
    /// bitwise-identical to `compute_all` (same executor fan-out, same
    /// index order, same per-node clock charges).
    pub fn compute_alive<T: Send>(
        &mut self,
        work: impl Fn(usize) -> T + Sync,
    ) -> Vec<Option<T>> {
        let ids = self.alive_ids();
        let timed = self.exec.run_timed_subset(&ids, work);
        let mut out: Vec<Option<T>> =
            (0..self.size()).map(|_| None).collect();
        for (&id, (v, secs)) in ids.iter().zip(timed) {
            self.nodes[id].advance_compute(secs);
            out[id] = Some(v);
        }
        out
    }

    /// Inline (never pooled) variant of [`Cluster::compute_alive`], the
    /// fault-aware [`Cluster::compute_all_inline`].
    pub fn compute_alive_inline<T>(
        &mut self,
        mut work: impl FnMut(usize) -> T,
    ) -> Vec<Option<T>> {
        let ids = self.alive_ids();
        let mut out: Vec<Option<T>> =
            (0..self.size()).map(|_| None).collect();
        for id in ids {
            let (v, secs) = Stopwatch::time(|| work(id));
            self.nodes[id].advance_compute(secs);
            out[id] = Some(v);
        }
        out
    }

    /// Run `work(k)` for every block k of an owner map, charging block
    /// k's measured time to machine `owners[k]` — how rebalanced runs
    /// keep per-block work attributable after adoption. With
    /// `owners[k] == k` this is bitwise-identical to `compute_all`.
    pub fn compute_owned<T: Send>(
        &mut self,
        owners: &[usize],
        work: impl Fn(usize) -> T + Sync,
    ) -> Vec<T> {
        let timed = self.exec.run_timed(owners.len(), work);
        timed
            .into_iter()
            .enumerate()
            .map(|(k, (v, secs))| {
                self.nodes[owners[k]].advance_compute(secs);
                v
            })
            .collect()
    }

    /// One point-to-point block transfer performed to move a dead
    /// machine's data onto survivor `to`: advances `to`'s clock by the
    /// transfer time and counts real traffic plus a rebalance event.
    pub fn rebalance_fetch(&mut self, to: usize, bytes: usize) {
        let t = self.nodes[to].clock() + self.net.transfer_time(bytes);
        self.nodes[to].wait_until(t);
        self.metrics.bytes_sent += bytes;
        self.metrics.messages += 1;
        self.metrics.faults.rebalances += 1;
        self.note_collective("collective.rebalance_fetch", bytes);
    }

    /// Synchronize alive clocks at the current (alive) makespan.
    pub fn barrier(&mut self) {
        let t = self.alive_max_clock();
        for n in self.nodes.iter_mut().filter(|n| n.alive()) {
            n.wait_until(t);
        }
    }

    /// Reduce `bytes`-sized values from all alive nodes to the master
    /// along a binomial tree: ceil(log2 Mₐ) rounds. Master ends at
    /// max(alive clocks) + rounds·transfer(bytes) + any fault delay.
    /// Returns ids that died during the exchange (retry exhaustion);
    /// empty on the direct transport.
    pub fn reduce_to_master(&mut self, bytes: usize) -> Vec<usize> {
        let ids = self.alive_ids();
        let ma = ids.len();
        if ma <= 1 {
            return Vec::new();
        }
        let root = self.master();
        let out = self.transport.exchange(&ids, Some(root), bytes);
        let (extra, failed) = self.apply_exchange(out);
        let t_done = self.alive_max_clock()
            + self.net.collective_time(ma, bytes)
            + extra;
        self.nodes[root].wait_until(t_done);
        self.metrics.bytes_sent += bytes * (ma - 1);
        self.metrics.messages += ma - 1;
        self.note_collective("collective.reduce", bytes * (ma - 1));
        failed
    }

    /// Broadcast `bytes` from the master to all alive nodes (binomial
    /// tree). Every receiver ends at master_clock + rounds·transfer +
    /// any fault delay. Returns newly-dead ids.
    pub fn bcast_from_master(&mut self, bytes: usize) -> Vec<usize> {
        let ids = self.alive_ids();
        let ma = ids.len();
        if ma <= 1 {
            return Vec::new();
        }
        let root = self.master();
        let out = self.transport.exchange(&ids, Some(root), bytes);
        let (extra, failed) = self.apply_exchange(out);
        let t_done = self.nodes[root].clock()
            + self.net.collective_time(ma, bytes)
            + extra;
        for n in self.nodes.iter_mut().filter(|n| n.alive()) {
            n.wait_until(t_done);
        }
        self.metrics.bytes_sent += bytes * (ma - 1);
        self.metrics.messages += ma - 1;
        self.note_collective("collective.bcast", bytes * (ma - 1));
        failed
    }

    /// Gather `bytes` from every alive node to the master: latency
    /// amortized over a tree (log Mₐ rounds) but the master still
    /// receives all the payload: rounds·latency + (Mₐ−1)·bytes/bw.
    /// Returns newly-dead ids.
    pub fn gather_to_master(&mut self, bytes: usize) -> Vec<usize> {
        let ids = self.alive_ids();
        let ma = ids.len();
        if ma <= 1 {
            return Vec::new();
        }
        let root = self.master();
        let out = self.transport.exchange(&ids, Some(root), bytes);
        let (extra, failed) = self.apply_exchange(out);
        let rounds = NetworkModel::tree_rounds(ma) as f64;
        let t = rounds * self.net.latency_s
            + ((ma - 1) * bytes) as f64 * 8.0
                / self.net.bandwidth_bps.max(f64::MIN_POSITIVE);
        let t_done = self.alive_max_clock() + t + extra;
        self.nodes[root].wait_until(t_done);
        self.metrics.bytes_sent += bytes * (ma - 1);
        self.metrics.messages += ma - 1;
        self.note_collective("collective.gather", bytes * (ma - 1));
        failed
    }

    /// Allreduce of `bytes` across all alive nodes (butterfly/recursive
    /// doubling): log Mₐ rounds, everyone ends synchronized at
    /// max(alive clocks) + rounds·transfer (the MPI_Allreduce/MAXLOC
    /// shape the row-based parallel ICF uses each iteration). Returns
    /// newly-dead ids.
    pub fn allreduce(&mut self, bytes: usize) -> Vec<usize> {
        let ids = self.alive_ids();
        let ma = ids.len();
        if ma <= 1 {
            return Vec::new();
        }
        let out = self.transport.exchange(&ids, None, bytes);
        let (extra, failed) = self.apply_exchange(out);
        let t_done = self.alive_max_clock()
            + self.net.collective_time(ma, bytes)
            + extra;
        for n in self.nodes.iter_mut().filter(|n| n.alive()) {
            n.wait_until(t_done);
        }
        // butterfly: every node sends one message per round
        let rounds = NetworkModel::tree_rounds(ma);
        self.metrics.bytes_sent += bytes * ma * rounds / 2;
        self.metrics.messages += ma * rounds / 2;
        self.note_collective("collective.allreduce", bytes * ma * rounds / 2);
        failed
    }

    /// All-to-all personalized exchange of `bytes` per pair (the pPIC
    /// clustering shuffle): each alive node sends Mₐ−1 messages.
    /// Returns newly-dead ids.
    pub fn alltoall(&mut self, bytes_per_pair: usize) -> Vec<usize> {
        let ids = self.alive_ids();
        let ma = ids.len();
        if ma <= 1 {
            return Vec::new();
        }
        let out = self.transport.exchange(&ids, None, bytes_per_pair);
        let (extra, failed) = self.apply_exchange(out);
        let per_node =
            (ma - 1) as f64 * self.net.transfer_time(bytes_per_pair);
        let t_done = self.alive_max_clock() + per_node + extra;
        for n in self.nodes.iter_mut().filter(|n| n.alive()) {
            n.wait_until(t_done);
        }
        self.metrics.bytes_sent += bytes_per_pair * ma * (ma - 1);
        self.metrics.messages += ma * (ma - 1);
        self.note_collective("collective.alltoall",
                             bytes_per_pair * ma * (ma - 1));
        failed
    }

    /// Mark the end of a named protocol phase. Fault counters are
    /// snapshotted so the [`Phase`] row carries the per-phase delta.
    /// Telemetry gets a `phase.{name}` span covering `[previous mark,
    /// now]` (parented to the caller's open protocol span) with the
    /// buffered collective events nested under it.
    pub fn phase(&mut self, name: &str) {
        let delta = self.metrics.faults.since(&self.phase_mark);
        self.phase_mark = self.metrics.faults.clone();
        self.emit_phase_span(name, &delta);
        self.metrics.phases.push(Phase {
            name: name.to_string(),
            end_makespan: self.makespan(),
            faults: delta,
        });
    }

    fn emit_phase_span(&mut self, name: &str, faults: &FaultCounters) {
        if !crate::obsv::enabled() {
            return;
        }
        use crate::obsv::{emit_span_at, FieldValue, Parent};
        let end = crate::obsv::now_ns();
        let fault_events = faults.retries
            + faults.timeouts
            + faults.deaths
            + faults.rebalances
            + faults.straggle_events;
        let pid = emit_span_at(
            &format!("phase.{name}"),
            self.phase_start_ns,
            end,
            Parent::Current,
            vec![
                ("faults", FieldValue::U64(fault_events as u64)),
                ("end_makespan_s", FieldValue::F64(self.makespan())),
            ],
        );
        for (kind, bytes, at) in self.obsv_events.drain(..) {
            emit_span_at(
                kind,
                at,
                at,
                Parent::Explicit(pid),
                vec![("bytes", FieldValue::U64(bytes))],
            );
        }
        self.phase_start_ns = end;
    }

    /// Finish the run and take the metrics. Telemetry: the run's
    /// traffic and fault totals publish into the registry as counters
    /// ([`RunMetrics`] itself is unchanged — the registry is the
    /// cross-run aggregate view, `RunMetrics` the per-run report).
    pub fn finish(mut self) -> RunMetrics {
        self.metrics.makespan = self.makespan();
        self.metrics.total_compute =
            self.nodes.iter().map(|n| n.compute_total()).sum();
        self.metrics.max_compute = self
            .nodes
            .iter()
            .map(|n| n.compute_total())
            .fold(0.0, f64::max);
        self.metrics.wall_s = self.wall.elapsed();
        self.metrics.threads = self.exec.workers();
        if crate::obsv::enabled() {
            use crate::obsv::{counter_add, counter_add_labeled, observe, Unit};
            let m = &self.metrics;
            counter_add("cluster.runs", 1);
            counter_add("cluster.bytes_sent", m.bytes_sent as u64);
            counter_add("cluster.messages", m.messages as u64);
            let f = &m.faults;
            for (kind, v) in [
                ("retries", f.retries),
                ("timeouts", f.timeouts),
                ("deaths", f.deaths),
                ("rebalances", f.rebalances),
                ("straggle_events", f.straggle_events),
            ] {
                if v > 0 {
                    counter_add_labeled("cluster.faults", kind, v as u64);
                }
            }
            observe("cluster.makespan_s", Unit::Seconds, m.makespan);
            observe("cluster.wall_s", Unit::Seconds, m.wall_s);
        }
        self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;
    use std::time::Duration;

    fn fast_net() -> NetworkModel {
        NetworkModel { latency_s: 1e-3, bandwidth_bps: 1e9 }
    }

    #[test]
    fn compute_all_advances_individual_clocks() {
        let mut c = Cluster::new(3, NetworkModel::instant());
        c.compute_all(|id| sleep(Duration::from_millis(2 * (id as u64 + 1))));
        // node 2 slept longest
        assert!(c.nodes[2].clock() > c.nodes[0].clock());
        // makespan is max clock, NOT the sum (that's the parallelism)
        let sum: f64 = c.nodes.iter().map(|n| n.clock()).sum();
        assert!(c.makespan() < sum);
    }

    #[test]
    fn reduce_only_advances_master_beyond_max() {
        let mut c = Cluster::new(4, fast_net());
        c.charge_compute(2, 0.5); // slowest worker
        c.reduce_to_master(1000);
        // master waited for node 2 plus 2 rounds of ~1ms
        assert!(c.nodes[MASTER].clock() >= 0.5 + 2.0 * 1e-3);
        // other workers unaffected
        assert_eq!(c.nodes[1].clock(), 0.0);
    }

    #[test]
    fn bcast_synchronizes_to_master_time() {
        let mut c = Cluster::new(4, fast_net());
        c.charge_compute(MASTER, 1.0);
        c.bcast_from_master(100);
        for n in &c.nodes {
            assert!(n.clock() >= 1.0);
        }
    }

    #[test]
    fn gather_cost_scales_with_payload() {
        let mut a = Cluster::new(8, fast_net());
        a.gather_to_master(1_000_000);
        let mut b = Cluster::new(8, fast_net());
        b.gather_to_master(10_000_000);
        assert!(b.nodes[MASTER].clock() > a.nodes[MASTER].clock());
    }

    #[test]
    fn traffic_accounting() {
        let mut c = Cluster::new(5, fast_net());
        c.reduce_to_master(10);
        c.bcast_from_master(20);
        let m = c.finish();
        assert_eq!(m.bytes_sent, 10 * 4 + 20 * 4);
        assert_eq!(m.messages, 8);
    }

    #[test]
    fn single_node_communication_free() {
        let mut c = Cluster::new(1, fast_net());
        c.reduce_to_master(1000);
        c.bcast_from_master(1000);
        c.alltoall(1000);
        let m = c.finish();
        assert_eq!(m.bytes_sent, 0);
        assert_eq!(m.makespan, 0.0);
    }

    #[test]
    fn phases_and_finish() {
        let mut c = Cluster::new(2, NetworkModel::instant());
        c.charge_compute(0, 1.0);
        c.phase("one");
        c.charge_compute(1, 3.0);
        c.phase("two");
        let m = c.finish();
        assert_eq!(m.phases.len(), 2);
        assert_eq!(m.phase_duration(0), 1.0);
        assert_eq!(m.phase_duration(1), 2.0); // makespan 1 -> 3
        assert_eq!(m.makespan, 3.0);
        assert_eq!(m.total_compute, 4.0);
        assert_eq!(m.max_compute, 3.0);
    }

    #[test]
    fn parallel_compute_all_matches_serial_and_advances_clocks() {
        let work = |id: usize| -> f64 {
            // deterministic per-node numeric work
            (0..2000).map(|k| ((id + 1) * (k + 1)) as f64).sum::<f64>().sqrt()
        };
        let mut serial = Cluster::new(4, NetworkModel::instant());
        let a = serial.compute_all(work);
        let mut par = Cluster::with_exec(4, NetworkModel::instant(),
                                         ParallelExecutor::threads(4));
        let b = par.compute_all(work);
        assert_eq!(a, b, "thread-parallel results must be identical");
        for n in &par.nodes {
            assert!(n.clock() > 0.0, "node {} clock did not advance", n.id);
        }
        let m = par.finish();
        assert_eq!(m.threads, 4);
        assert!(m.wall_s > 0.0);
    }

    #[test]
    fn finish_records_serial_executor() {
        let mut c = Cluster::new(2, NetworkModel::instant());
        c.charge_compute(0, 0.1);
        let m = c.finish();
        assert_eq!(m.threads, 1);
        assert!(m.wall_s >= 0.0);
    }

    #[test]
    fn barrier_aligns_clocks() {
        let mut c = Cluster::new(3, NetworkModel::instant());
        c.charge_compute(1, 2.0);
        c.barrier();
        for n in &c.nodes {
            assert_eq!(n.clock(), 2.0);
        }
    }

    use super::super::fault::FaultPlan;
    use super::super::transport::FaultTransport;

    fn fault_cluster(m: usize, plan: FaultPlan) -> Cluster {
        Cluster::with_transport(
            m,
            fast_net(),
            ParallelExecutor::serial(),
            Box::new(FaultTransport::new(plan)),
        )
    }

    /// The zero-fault transport reproduces the direct path bitwise:
    /// same clocks, same traffic, no fault counters.
    #[test]
    fn zero_fault_transport_matches_direct_bitwise() {
        let run = |mut c: Cluster| {
            c.charge_compute(2, 0.25);
            let f1 = c.reduce_to_master(100);
            let f2 = c.bcast_from_master(200);
            let f3 = c.gather_to_master(50);
            let f4 = c.allreduce(16);
            let f5 = c.alltoall(8);
            assert!(f1.is_empty() && f2.is_empty() && f3.is_empty()
                        && f4.is_empty() && f5.is_empty());
            c.phase("p");
            let clocks: Vec<u64> =
                c.nodes.iter().map(|n| n.clock().to_bits()).collect();
            (clocks, c.finish())
        };
        let (dc, dm) = run(Cluster::new(4, fast_net()));
        let (fc, fm) = run(fault_cluster(4, FaultPlan::seeded(11)));
        assert_eq!(dc, fc, "clocks diverged");
        assert_eq!(dm.bytes_sent, fm.bytes_sent);
        assert_eq!(dm.messages, fm.messages);
        assert_eq!(dm.makespan.to_bits(), fm.makespan.to_bits());
        assert!(fm.faults.is_zero());
        assert_eq!(fm.phases[0].faults, FaultCounters::default());
    }

    /// Stragglers delay clocks and are counted, but never change the
    /// traffic accounting.
    #[test]
    fn stragglers_delay_and_count() {
        let plan = FaultPlan::seeded(5).with_stragglers(1.0, 1e-2);
        let mut c = fault_cluster(3, plan);
        let failed = c.reduce_to_master(10);
        assert!(failed.is_empty());
        let m_base = {
            let mut b = Cluster::new(3, fast_net());
            b.reduce_to_master(10);
            b.finish()
        };
        let m = c.finish();
        assert_eq!(m.faults.straggle_events, 3);
        assert!((m.faults.straggle_s - 3e-2).abs() < 1e-15);
        assert_eq!(m.bytes_sent, m_base.bytes_sent);
        assert_eq!(m.messages, m_base.messages);
        assert!(m.makespan > m_base.makespan);
    }

    /// Certain drops exhaust retries: the non-root participants die,
    /// deaths are counted, and they leave subsequent collectives.
    #[test]
    fn retry_exhaustion_kills_and_shrinks_collectives() {
        let plan = FaultPlan::seeded(2)
            .with_drops(1.0, 1)
            .with_timeout(1e-4, 2.0);
        let mut c = fault_cluster(3, plan);
        let failed = c.reduce_to_master(10);
        assert_eq!(failed, vec![1, 2]);
        assert_eq!(c.alive_ids(), vec![0]);
        assert!(!c.is_alive(1));
        // a 1-alive cluster is communication-free again
        assert!(c.bcast_from_master(10).is_empty());
        let m = c.finish();
        assert_eq!(m.faults.deaths, 2);
        assert!(m.faults.timeouts > 0);
    }

    /// Scheduled deaths drain at phase entry; the master re-elects to
    /// the lowest alive index.
    #[test]
    fn scheduled_death_and_reelection() {
        let plan = FaultPlan::none().kill(0, "summary");
        let mut c = fault_cluster(4, plan);
        assert_eq!(c.master(), 0);
        assert_eq!(c.take_deaths("summary"), vec![0]);
        assert!(c.take_deaths("summary").is_empty());
        assert_eq!(c.master(), 1);
        assert_eq!(c.alive_ids(), vec![1, 2, 3]);
        let m = c.finish();
        assert_eq!(m.faults.deaths, 1);
    }

    /// compute_alive returns None at dead indices and Some elsewhere,
    /// matching compute_all at the alive ones.
    #[test]
    fn compute_alive_skips_dead() {
        let mut c = fault_cluster(3, FaultPlan::none().kill(1, "x"));
        c.take_deaths("x");
        let out = c.compute_alive(|id| id * 10);
        assert_eq!(out, vec![Some(0), None, Some(20)]);
        assert_eq!(c.nodes[1].clock(), 0.0);
        let inline = c.compute_alive_inline(|id| id + 1);
        assert_eq!(inline, vec![Some(1), None, Some(3)]);
    }

    /// compute_owned charges each block's time to its owner.
    #[test]
    fn compute_owned_charges_owner() {
        let mut c = Cluster::new(3, NetworkModel::instant());
        let owners = vec![0, 2, 2];
        let out = c.compute_owned(&owners, |k| {
            sleep(Duration::from_millis(1));
            k
        });
        assert_eq!(out, vec![0, 1, 2]);
        assert_eq!(c.nodes[1].clock(), 0.0);
        assert!(c.nodes[2].compute_total() >= c.nodes[0].compute_total());
    }

    /// rebalance_fetch moves real bytes and counts a rebalance.
    #[test]
    fn rebalance_fetch_accounting() {
        let mut c = Cluster::new(2, fast_net());
        c.rebalance_fetch(1, 500);
        let m = c.finish();
        assert_eq!(m.bytes_sent, 500);
        assert_eq!(m.messages, 1);
        assert_eq!(m.faults.rebalances, 1);
    }

    /// A scoped telemetry registry sees the phase span (nested under
    /// the caller's protocol span, collective events inside) and the
    /// run's traffic counters from `finish()` — while `RunMetrics`
    /// itself stays untouched.
    #[test]
    fn telemetry_spans_and_counters() {
        use crate::obsv::{Registry, SnapshotMode};
        use std::sync::Arc;
        let reg = Arc::new(Registry::new());
        let _g = reg.install();
        let proto = crate::obsv::span("protocol.test");
        let mut c = Cluster::new(4, fast_net());
        c.reduce_to_master(10);
        c.phase("one");
        let m = c.finish();
        drop(proto);
        let snap = reg.snapshot(SnapshotMode::Full);
        assert_eq!(snap.counters["cluster.runs"], 1);
        assert_eq!(snap.counters["cluster.bytes_sent"] as usize,
                   m.bytes_sent);
        assert_eq!(snap.counters["cluster.messages"] as usize, m.messages);
        assert_eq!(snap.spans.len(), 1);
        let p = &snap.spans[0];
        assert_eq!(p.name, "protocol.test");
        assert_eq!(p.children.len(), 1);
        assert_eq!(p.children[0].name, "phase.one");
        assert_eq!(p.children[0].children.len(), 1);
        assert_eq!(p.children[0].children[0].name, "collective.reduce");
        // the collective event carries the bytes it moved
        let (k, v) = &p.children[0].children[0].fields[0];
        assert_eq!(k, "bytes");
        assert_eq!(v.as_usize().unwrap(), m.bytes_sent);
    }

    /// Per-phase fault rows carry deltas, not cumulative counts.
    #[test]
    fn phase_fault_deltas() {
        let plan = FaultPlan::seeded(9).with_stragglers(1.0, 1e-3);
        let mut c = fault_cluster(2, plan);
        c.reduce_to_master(10);
        c.phase("a");
        c.reduce_to_master(10);
        c.reduce_to_master(10);
        c.phase("b");
        let m = c.finish();
        assert_eq!(m.phases[0].faults.straggle_events, 2);
        assert_eq!(m.phases[1].faults.straggle_events, 4);
        assert_eq!(m.faults.straggle_events, 6);
    }
}
