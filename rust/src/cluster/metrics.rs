//! Per-run accounting: phases, traffic, and the incurred-time breakdown.

use super::fault::FaultCounters;

/// One labelled phase of a protocol run.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub name: String,
    /// makespan when the phase completed (seconds)
    pub end_makespan: f64,
    /// fault events that occurred during this phase
    pub faults: FaultCounters,
}

/// Metrics of one simulated protocol run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    pub phases: Vec<Phase>,
    /// total bytes moved over the simulated network
    pub bytes_sent: usize,
    /// number of point-to-point messages (tree collectives count their
    /// rounds × participants)
    pub messages: usize,
    /// final makespan = incurred time the paper plots
    pub makespan: f64,
    /// sum over nodes of pure compute seconds
    pub total_compute: f64,
    /// max over nodes of pure compute seconds (critical-path compute)
    pub max_compute: f64,
    /// real host wall-clock seconds for the whole run (creation →
    /// `finish`). Under the serial executor this tracks `total_compute`;
    /// under a thread-parallel executor it approaches `max_compute` —
    /// the gap is the realized speedup.
    pub wall_s: f64,
    /// host worker threads that executed node compute (1 = serial)
    pub threads: usize,
    /// whole-run fault accounting (all-zero on the direct transport)
    pub faults: FaultCounters,
}

impl RunMetrics {
    /// Duration of phase `i` (difference of successive end makespans).
    pub fn phase_duration(&self, i: usize) -> f64 {
        let end = self.phases[i].end_makespan;
        let start = if i == 0 { 0.0 } else { self.phases[i - 1].end_makespan };
        end - start
    }

    /// Find a phase by name.
    pub fn phase(&self, name: &str) -> Option<&Phase> {
        self.phases.iter().find(|p| p.name == name)
    }

    /// Communication share of the makespan (everything that is not
    /// critical-path compute).
    pub fn comm_fraction(&self) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            ((self.makespan - self.max_compute) / self.makespan).max(0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_durations() {
        let m = RunMetrics {
            phases: vec![
                Phase { name: "a".into(), end_makespan: 1.0,
                        faults: FaultCounters::default() },
                Phase { name: "b".into(), end_makespan: 3.5,
                        faults: FaultCounters::default() },
            ],
            makespan: 3.5,
            ..Default::default()
        };
        assert_eq!(m.phase_duration(0), 1.0);
        assert_eq!(m.phase_duration(1), 2.5);
        assert_eq!(m.phase("b").unwrap().end_makespan, 3.5);
        assert!(m.phase("c").is_none());
    }

    #[test]
    fn comm_fraction_bounds() {
        let m = RunMetrics { makespan: 2.0, max_compute: 1.5, ..Default::default() };
        assert!((m.comm_fraction() - 0.25).abs() < 1e-12);
        let z = RunMetrics::default();
        assert_eq!(z.comm_fraction(), 0.0);
    }
}
