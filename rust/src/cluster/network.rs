//! Network cost model: per-message latency + bandwidth, gigabit default.

/// Point-to-point message cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkModel {
    /// one-way message latency (seconds)
    pub latency_s: f64,
    /// link bandwidth (bits per second)
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// Gigabit Ethernet as in the paper's cluster: ~50 µs latency, 1 Gb/s.
    pub fn gigabit() -> NetworkModel {
        NetworkModel { latency_s: 50e-6, bandwidth_bps: 1e9 }
    }

    /// Zero-cost network (ablations: isolate compute).
    pub fn instant() -> NetworkModel {
        NetworkModel { latency_s: 0.0, bandwidth_bps: f64::INFINITY }
    }

    /// Time for one point-to-point message of `bytes`.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + (bytes as f64) * 8.0 / self.bandwidth_bps
    }

    /// Rounds of a binomial-tree collective over `m` participants.
    pub fn tree_rounds(m: usize) -> usize {
        if m <= 1 {
            0
        } else {
            usize::BITS as usize - (m - 1).leading_zeros() as usize
        }
    }

    /// Cost of a broadcast/reduce of `bytes` over `m` nodes:
    /// ceil(log2 m) rounds of one message each (the paper's O(log M)).
    pub fn collective_time(&self, m: usize, bytes: usize) -> f64 {
        Self::tree_rounds(m) as f64 * self.transfer_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn transfer_time_components() {
        let net = NetworkModel { latency_s: 1e-3, bandwidth_bps: 8e6 };
        // 1000 bytes = 8000 bits over 8 Mb/s = 1 ms + 1 ms latency
        assert_close(net.transfer_time(1000), 2e-3, 1e-12, 0.0);
    }

    #[test]
    fn tree_rounds_log2() {
        assert_eq!(NetworkModel::tree_rounds(1), 0);
        assert_eq!(NetworkModel::tree_rounds(2), 1);
        assert_eq!(NetworkModel::tree_rounds(3), 2);
        assert_eq!(NetworkModel::tree_rounds(4), 2);
        assert_eq!(NetworkModel::tree_rounds(5), 3);
        assert_eq!(NetworkModel::tree_rounds(16), 4);
        assert_eq!(NetworkModel::tree_rounds(20), 5);
    }

    #[test]
    fn collective_scales_logarithmically() {
        let net = NetworkModel::gigabit();
        let t4 = net.collective_time(4, 1024);
        let t16 = net.collective_time(16, 1024);
        assert_close(t16 / t4, 2.0, 1e-12, 0.0); // 4 rounds vs 2
    }

    #[test]
    fn instant_network_free() {
        let net = NetworkModel::instant();
        assert_eq!(net.transfer_time(1 << 30), 0.0);
        assert_eq!(net.collective_time(20, 1 << 20), 0.0);
    }

    use crate::testkit::prop::prop_check;

    /// Edge cases: m=0 and m=1 are round-free; powers of two hit
    /// exactly log2, and ±1 around them moves the count by exactly one
    /// (the ceil(log2 m) shape every makespan number sits on).
    #[test]
    fn tree_rounds_edges_and_power_boundaries() {
        assert_eq!(NetworkModel::tree_rounds(0), 0);
        assert_eq!(NetworkModel::tree_rounds(1), 0);
        assert_eq!(NetworkModel::tree_rounds(2), 1);
        for k in 2..16usize {
            let p = 1usize << k;
            assert_eq!(NetworkModel::tree_rounds(p), k, "m=2^{k}");
            assert_eq!(NetworkModel::tree_rounds(p + 1), k + 1,
                       "m=2^{k}+1");
            assert_eq!(NetworkModel::tree_rounds(p - 1), k, "m=2^{k}-1");
        }
    }

    /// tree_rounds is monotone non-decreasing in m.
    #[test]
    fn prop_tree_rounds_monotone() {
        prop_check("tree-rounds-monotone", 128, |g| {
            let m = g.usize_in(0, 1 << 20);
            assert!(NetworkModel::tree_rounds(m)
                        <= NetworkModel::tree_rounds(m + 1));
        });
    }

    /// collective_time is monotone in both machine count and payload
    /// for any positive-latency, finite-bandwidth network.
    #[test]
    fn prop_collective_time_monotone() {
        prop_check("collective-monotone", 128, |g| {
            let net = NetworkModel {
                latency_s: g.f64_in(1e-7, 1e-2),
                bandwidth_bps: g.f64_in(1e6, 1e11),
            };
            let m = g.usize_in(1, 64);
            let bytes = g.usize_in(0, 1 << 24);
            let t = net.collective_time(m, bytes);
            assert!(t >= 0.0);
            assert!(t <= net.collective_time(m + 1, bytes) + 1e-18,
                    "m-monotonicity: m={m} bytes={bytes}");
            assert!(t <= net.collective_time(m, bytes + 1) + 1e-18,
                    "byte-monotonicity: m={m} bytes={bytes}");
            // exactly rounds × one transfer
            let want = NetworkModel::tree_rounds(m) as f64
                * net.transfer_time(bytes);
            assert_eq!(t.to_bits(), want.to_bits());
        });
    }

    /// The instant network is free for any payload/participant count,
    /// and transfer_time reduces to pure latency at zero bytes.
    #[test]
    fn prop_transfer_time_instant_and_latency() {
        prop_check("transfer-instant", 64, |g| {
            let bytes = g.usize_in(0, 1 << 30);
            let m = g.usize_in(0, 1024);
            let inst = NetworkModel::instant();
            assert_eq!(inst.transfer_time(bytes), 0.0);
            assert_eq!(inst.collective_time(m, bytes), 0.0);
            let lat = g.f64_in(1e-9, 1e-1);
            let net = NetworkModel { latency_s: lat, bandwidth_bps: 1e9 };
            assert_eq!(net.transfer_time(0), lat);
            assert!(net.transfer_time(bytes) >= lat);
        });
    }
}
