//! Fault model for the simulated cluster: a seeded, deterministic
//! [`FaultPlan`] describing stragglers, message drops (with
//! timeout/bounded-retry/backoff) and scheduled machine deaths, plus
//! the [`FaultCounters`] that [`crate::cluster::RunMetrics`] accumulates
//! and the typed [`MachinesLost`] error runs return when every machine
//! is gone.
//!
//! The paper's cluster model is failure-free; this module is the
//! deliberately-small deviation that lets the protocols keep their
//! Theorem 1–3 equivalence discipline *under* injected faults: with a
//! zero plan the fault-aware path is bitwise-identical to the direct
//! path, and any non-zero plan is a pure function of `(seed, event
//! order)` — never of measured wall times — so chaos runs replay
//! exactly.

use std::fmt;

/// Deterministic fault-injection schedule for one cluster run.
///
/// All probabilities are rolled from a PRNG seeded by `seed` in a fixed
/// event order (participants ascending per exchange), so two runs with
/// the same plan produce bitwise-identical predictions, counters and
/// traffic. Virtual-time knobs (`timeout_s`, `straggler_delay_s`,
/// `backoff`) only move node clocks; they never reorder reductions.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// PRNG seed for all probabilistic decisions.
    pub seed: u64,
    /// Per-participant, per-attempt probability a message is dropped.
    pub drop_prob: f64,
    /// Retries after the first attempt before a node is declared dead.
    pub max_retries: usize,
    /// Virtual seconds the sender waits before detecting a drop.
    pub timeout_s: f64,
    /// Multiplier applied to the timeout on each successive retry.
    pub backoff: f64,
    /// Per-participant probability an exchange straggles.
    pub straggler_prob: f64,
    /// Virtual seconds a straggling participant is delayed.
    pub straggler_delay_s: f64,
    /// Scheduled deaths: (machine id, phase name) — the machine is
    /// discovered dead when the protocol enters that phase.
    pub deaths: Vec<(usize, String)>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            drop_prob: 0.0,
            max_retries: 3,
            timeout_s: 1e-3,
            backoff: 2.0,
            straggler_prob: 0.0,
            straggler_delay_s: 0.0,
            deaths: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// The zero plan: no drops, no stragglers, no deaths. Runs through
    /// the fault transport with this plan are bitwise-identical to the
    /// direct path (the equivalence oracle the chaos suite pins).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Zero plan with a chosen PRNG seed (a convenience root for the
    /// builder methods below).
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { seed, ..FaultPlan::default() }
    }

    /// Enable message drops at probability `prob` per participant per
    /// attempt, with `max_retries` retries before declaring death.
    pub fn with_drops(mut self, prob: f64, max_retries: usize) -> FaultPlan {
        assert!((0.0..=1.0).contains(&prob), "drop_prob {prob}");
        self.drop_prob = prob;
        self.max_retries = max_retries;
        self
    }

    /// Set the drop-detection timeout and per-retry backoff multiplier.
    pub fn with_timeout(mut self, timeout_s: f64, backoff: f64) -> FaultPlan {
        assert!(timeout_s >= 0.0 && backoff >= 1.0);
        self.timeout_s = timeout_s;
        self.backoff = backoff;
        self
    }

    /// Enable stragglers: each participant in each exchange is delayed
    /// by `delay_s` virtual seconds with probability `prob`.
    pub fn with_stragglers(mut self, prob: f64, delay_s: f64) -> FaultPlan {
        assert!((0.0..=1.0).contains(&prob), "straggler_prob {prob}");
        assert!(delay_s >= 0.0);
        self.straggler_prob = prob;
        self.straggler_delay_s = delay_s;
        self
    }

    /// Schedule machine `machine` to die when the run reaches `phase`.
    pub fn kill(mut self, machine: usize, phase: &str) -> FaultPlan {
        self.deaths.push((machine, phase.to_string()));
        self
    }

    /// Whether this plan can perturb a run at all.
    pub fn has_faults(&self) -> bool {
        self.drop_prob > 0.0
            || (self.straggler_prob > 0.0 && self.straggler_delay_s > 0.0)
            || !self.deaths.is_empty()
    }
}

/// Fault-event accounting, accumulated per run and per phase.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultCounters {
    /// Successful resends after a dropped message.
    pub retries: usize,
    /// Drop-detection timeouts charged (one per failed attempt).
    pub timeouts: usize,
    /// Machines declared dead (scheduled or retry-exhausted).
    pub deaths: usize,
    /// Point-to-point block transfers performed to rebalance dead
    /// machines' data onto survivors.
    pub rebalances: usize,
    /// Straggler events injected.
    pub straggle_events: usize,
    /// Total virtual seconds of straggler delay injected.
    pub straggle_s: f64,
}

impl FaultCounters {
    /// True when no fault event of any kind has been recorded.
    pub fn is_zero(&self) -> bool {
        *self == FaultCounters::default()
    }

    /// Counter delta since an earlier snapshot (for per-phase rows).
    pub fn since(&self, earlier: &FaultCounters) -> FaultCounters {
        FaultCounters {
            retries: self.retries - earlier.retries,
            timeouts: self.timeouts - earlier.timeouts,
            deaths: self.deaths - earlier.deaths,
            rebalances: self.rebalances - earlier.rebalances,
            straggle_events: self.straggle_events - earlier.straggle_events,
            straggle_s: self.straggle_s - earlier.straggle_s,
        }
    }
}

/// Typed terminal error: every machine died before the run could
/// produce predictions. Anything short of this completes with
/// degraded-but-well-defined output.
#[derive(Debug, Clone, PartialEq)]
pub struct MachinesLost {
    /// Protocol phase during which the last machine was lost.
    pub phase: String,
    /// Machines lost over the whole run.
    pub machines: usize,
}

impl MachinesLost {
    pub fn at(phase: &str, machines: usize) -> MachinesLost {
        MachinesLost { phase: phase.to_string(), machines }
    }
}

impl fmt::Display for MachinesLost {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "all machines lost during phase '{}' ({} machine(s) died)",
            self.phase, self.machines
        )
    }
}

impl std::error::Error for MachinesLost {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_plan_has_no_faults() {
        assert!(!FaultPlan::none().has_faults());
        assert!(!FaultPlan::seeded(42).has_faults());
        // straggler probability without delay is inert
        assert!(!FaultPlan::none().with_stragglers(0.5, 0.0).has_faults());
    }

    #[test]
    fn builders_flag_faults() {
        assert!(FaultPlan::seeded(1).with_drops(0.1, 2).has_faults());
        assert!(FaultPlan::seeded(1).with_stragglers(0.3, 1e-4).has_faults());
        assert!(FaultPlan::none().kill(2, "predict").has_faults());
        let p = FaultPlan::seeded(7)
            .with_drops(0.25, 4)
            .with_timeout(1e-4, 1.5)
            .with_stragglers(0.5, 2e-3)
            .kill(0, "global_summary");
        assert_eq!(p.seed, 7);
        assert_eq!(p.max_retries, 4);
        assert_eq!(p.deaths, vec![(0, "global_summary".to_string())]);
    }

    #[test]
    fn counters_delta_and_zero() {
        let mut c = FaultCounters::default();
        assert!(c.is_zero());
        c.retries = 3;
        c.timeouts = 5;
        c.straggle_s = 0.25;
        let earlier = FaultCounters { retries: 1, timeouts: 2,
                                      ..Default::default() };
        let d = c.since(&earlier);
        assert_eq!(d.retries, 2);
        assert_eq!(d.timeouts, 3);
        assert_eq!(d.straggle_s, 0.25);
        assert!(!d.is_zero());
    }

    #[test]
    fn machines_lost_display() {
        let e = MachinesLost::at("reduce", 4);
        let msg = e.to_string();
        assert!(msg.contains("reduce"), "{msg}");
        assert!(msg.contains('4'), "{msg}");
    }
}
