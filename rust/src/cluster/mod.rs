//! Discrete-event cluster simulator.
//!
//! Stands in for the paper's testbed (20 Xeon nodes on gigabit Ethernet
//! running MPI) on a single host: every virtual node carries its own
//! clock; *compute* advances a node's clock by the **measured wall time**
//! of the real work executed for that node, and *communication* advances
//! clocks by a gigabit-network cost model with `O(log M)`-round
//! collectives (Pjesivac-Grbovic et al. 2007 — the model the paper's
//! Table 1 communication column assumes). Incurred time of a simulated
//! run is the makespan (max node clock), which is what the paper plots.
//!
//! See DESIGN.md §Substitutions for why this preserves the paper's
//! time/speedup *shape* even though absolute numbers differ.
//!
//! Execution is pluggable via [`ParallelExecutor`]: the default runs
//! node work serially on the host; `ParallelExecutor::threads(n)` runs
//! each virtual machine's work concurrently on a real thread pool, so
//! the host finishes in ~makespan rather than the serial sum while the
//! virtual-clock model (and hence every modeled metric) is unchanged.

pub mod exec;
pub mod fault;
pub mod metrics;
pub mod mpi;
pub mod network;
pub mod node;
pub mod transport;

pub use exec::ParallelExecutor;
pub use fault::{FaultCounters, FaultPlan, MachinesLost};
pub use metrics::RunMetrics;
pub use mpi::Cluster;
pub use network::NetworkModel;
pub use node::Node;
pub use transport::{DirectTransport, FaultTransport, Transport};
