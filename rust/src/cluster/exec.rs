//! The cluster's execution engine: serial or truly thread-parallel.
//!
//! The simulated [`super::Cluster`] advances per-node *virtual* clocks by
//! the measured wall time of each node's work — that is the paper's
//! analytical model and it holds whether the host executes the nodes one
//! after another or concurrently. A [`ParallelExecutor`] makes the
//! execution itself concurrent: per-machine tasks (Step 2 local
//! summaries, Step 4 block predictions, per-iteration pICF slab updates)
//! are fanned out over the scoped [`crate::util::pool::ThreadPool`], so a
//! multicore host finishes a protocol run in roughly the makespan rather
//! than the serial sum of node compute.
//!
//! Correctness: every task is a pure function of its machine index and
//! results are collected back in index order, so the thread-parallel run
//! is numerically **identical** to the serial one (asserted to ≤1e-10 by
//! `tests/integration_parallel_exec.rs`, and by construction bitwise —
//! no reduction order changes). Virtual clocks still advance by each
//! task's own measured time; the *real* elapsed time is reported
//! separately as [`super::RunMetrics::wall_s`].
//!
//! Caveat on the modeled clocks: per-task measurement under concurrency
//! includes whatever slowdown core contention causes, so with more
//! threads than cores (or a memory-bandwidth-bound workload) the
//! modeled makespan drifts upward relative to a serial run. Predictions
//! are unaffected — only timing-faithful sweeps should prefer the
//! serial executor or `threads <= physical cores`.

use std::fmt;
use std::sync::Arc;

use crate::linalg::LinalgCtx;
use crate::util::pool::ThreadPool;
use crate::util::Stopwatch;

/// Runs per-machine closures either inline (serial) or on a shared
/// thread pool. Cheap to clone — clones share the same pool.
#[derive(Clone, Default)]
pub struct ParallelExecutor {
    pool: Option<Arc<ThreadPool>>,
}

impl ParallelExecutor {
    /// Execute node work inline, one node at a time (the seed behavior;
    /// also what `Default` gives you).
    pub fn serial() -> ParallelExecutor {
        ParallelExecutor { pool: None }
    }

    /// Execute node work on `n` real worker threads. `n <= 1` degrades
    /// to [`ParallelExecutor::serial`] — no pool, no thread overhead.
    pub fn threads(n: usize) -> ParallelExecutor {
        if n <= 1 {
            ParallelExecutor::serial()
        } else {
            ParallelExecutor { pool: Some(Arc::new(ThreadPool::new(n))) }
        }
    }

    /// Number of host worker threads (1 when serial).
    pub fn workers(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(1)
    }

    /// True when backed by a real thread pool.
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// A [`LinalgCtx`] sharing this executor's pool, for master-side
    /// block math (global-summary Cholesky, support-set Gram, …) to
    /// run thread-parallel on the same workers that execute node
    /// tasks. Safe to pass *into* node closures too: on a worker
    /// thread the ctx degrades to serial automatically (see
    /// [`LinalgCtx::pool`]), so per-node math never deadlocks the pool
    /// it runs on. Serial executors yield a serial ctx.
    pub fn linalg_ctx(&self) -> LinalgCtx {
        match &self.pool {
            Some(p) => LinalgCtx::pooled(Arc::clone(p)),
            None => LinalgCtx::serial(),
        }
    }

    /// Run `f(0), …, f(n-1)`, returning each task's result together with
    /// its own measured wall seconds, in index order. In parallel mode
    /// the tasks run concurrently on the pool; each task still times
    /// only itself, so per-node virtual clock charges are mode-agnostic.
    ///
    /// `n <= 1` always runs inline — a single task gains nothing from
    /// the pool, and hot paths issue many single-task calls (e.g. one
    /// full batch flushing in the serving loop).
    pub fn run_timed<T: Send>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Sync,
    ) -> Vec<(T, f64)> {
        match &self.pool {
            Some(pool) if n > 1 => pool.par_map(n, |i| Stopwatch::time(|| f(i))),
            _ => (0..n).map(|i| Stopwatch::time(|| f(i))).collect(),
        }
    }

    /// Like [`ParallelExecutor::run_timed`] but over an explicit id
    /// set: run `f(ids[0]), …, f(ids[last])`, returning results with
    /// per-task measured seconds in `ids` order. The fault-aware
    /// cluster path uses this to fan out only the *alive* machines.
    pub fn run_timed_subset<T: Send>(
        &self,
        ids: &[usize],
        f: impl Fn(usize) -> T + Sync,
    ) -> Vec<(T, f64)> {
        match &self.pool {
            Some(pool) if ids.len() > 1 => {
                pool.par_map(ids.len(), |k| Stopwatch::time(|| f(ids[k])))
            }
            _ => ids.iter().map(|&i| Stopwatch::time(|| f(i))).collect(),
        }
    }
}

impl fmt::Debug for ParallelExecutor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.pool {
            None => write!(f, "ParallelExecutor::serial"),
            Some(p) => write!(f, "ParallelExecutor::threads({})", p.workers()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_and_parallel_agree() {
        let serial = ParallelExecutor::serial();
        let par = ParallelExecutor::threads(4);
        let work = |i: usize| (0..100).map(|k| (i * k) as f64).sum::<f64>();
        let a: Vec<f64> =
            serial.run_timed(16, work).into_iter().map(|(v, _)| v).collect();
        let b: Vec<f64> =
            par.run_timed(16, work).into_iter().map(|(v, _)| v).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn per_task_times_nonnegative() {
        let par = ParallelExecutor::threads(2);
        for (_, secs) in par.run_timed(8, |i| i * 2) {
            assert!(secs >= 0.0);
        }
    }

    #[test]
    fn linalg_ctx_mirrors_executor_mode() {
        assert!(!ParallelExecutor::serial().linalg_ctx().is_pooled());
        let par = ParallelExecutor::threads(3);
        let ctx = par.linalg_ctx();
        assert!(ctx.is_pooled());
        assert_eq!(ctx.workers(), 3);
    }

    #[test]
    fn one_thread_degrades_to_serial() {
        let e = ParallelExecutor::threads(1);
        assert!(!e.is_parallel());
        assert_eq!(e.workers(), 1);
        assert_eq!(format!("{e:?}"), "ParallelExecutor::serial");
    }

    #[test]
    fn clones_share_the_pool() {
        let e = ParallelExecutor::threads(3);
        let c = e.clone();
        assert_eq!(c.workers(), 3);
        // both clones usable concurrently-ish (sequential here): the
        // Arc'd pool serves either without respawning threads
        let _ = e.run_timed(4, |i| i);
        let _ = c.run_timed(4, |i| i);
    }

    #[test]
    fn subset_matches_full_on_identity_ids() {
        let serial = ParallelExecutor::serial();
        let par = ParallelExecutor::threads(3);
        let ids: Vec<usize> = (0..9).collect();
        let work = |i: usize| i * i + 1;
        let full: Vec<usize> =
            serial.run_timed(9, work).into_iter().map(|(v, _)| v).collect();
        for e in [&serial, &par] {
            let sub: Vec<usize> = e
                .run_timed_subset(&ids, work)
                .into_iter()
                .map(|(v, _)| v)
                .collect();
            assert_eq!(sub, full);
        }
        // sparse subset preserves ids order
        let sparse: Vec<usize> = par
            .run_timed_subset(&[7, 2, 4], work)
            .into_iter()
            .map(|(v, _)| v)
            .collect();
        assert_eq!(sparse, vec![50, 5, 17]);
    }

    #[test]
    fn results_in_index_order() {
        let par = ParallelExecutor::threads(4);
        let out: Vec<usize> = par
            .run_timed(32, |i| {
                // stagger completion to stress ordering
                std::thread::sleep(std::time::Duration::from_micros(
                    ((32 - i) % 5) as u64 * 100,
                ));
                i
            })
            .into_iter()
            .map(|(v, _)| v)
            .collect();
        assert_eq!(out, (0..32).collect::<Vec<_>>());
    }
}
