//! Transport abstraction between the protocol layer and the simulated
//! network: every collective and point-to-point exchange in
//! [`crate::cluster::Cluster`] consults a [`Transport`] before the
//! [`crate::cluster::NetworkModel`] timing is applied.
//!
//! Two implementations:
//!
//! * [`DirectTransport`] — the failure-free in-process path. Returns
//!   the zero [`ExchangeOutcome`] for every exchange; `Cluster`
//!   degenerates to exactly its historical behavior (the fast default
//!   and the bitwise equivalence oracle for the chaos suite).
//! * [`FaultTransport`] — rolls a seeded PRNG per exchange according to
//!   a [`FaultPlan`]: straggler delays, per-participant drop/retry
//!   loops with exponential-backoff timeouts, and retry-exhaustion
//!   deaths, plus scheduled phase-entry deaths drained via
//!   [`Transport::take_deaths`].
//!
//! Determinism contract: outcomes are a pure function of the plan and
//! the deterministic event order (participants visited ascending; RNG
//! consumed only when the corresponding probability is > 0). Outcomes
//! never depend on measured wall times, so a plan replays bitwise.

use super::fault::FaultPlan;
use crate::util::Pcg64;

/// What the transport decided for one exchange.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExchangeOutcome {
    /// Extra virtual seconds the exchange takes (timeout waits).
    pub extra_delay_s: f64,
    /// Dropped messages that were successfully resent.
    pub retries: usize,
    /// Drop-detection timeouts charged.
    pub timeouts: usize,
    /// Per-participant straggler delays: (machine id, seconds).
    pub straggles: Vec<(usize, f64)>,
    /// Participants whose retries were exhausted — now dead.
    pub failed: Vec<usize>,
}

/// Mediates every exchange the cluster performs.
///
/// `root` is `Some(r)` for rooted collectives (reduce/bcast/gather):
/// the root cannot drop out of its own collective (it is the detector,
/// not a remote sender), so drop rolls skip it — it can still straggle,
/// and it can still die via a scheduled [`FaultPlan::kill`].
pub trait Transport: Send + std::fmt::Debug {
    /// Roll faults for one exchange among `participants`.
    fn exchange(
        &mut self,
        participants: &[usize],
        root: Option<usize>,
        bytes: usize,
    ) -> ExchangeOutcome;

    /// Drain scheduled deaths for the phase the protocol just entered.
    fn take_deaths(&mut self, phase: &str) -> Vec<usize>;
}

/// The failure-free path: zero outcome, no deaths, no PRNG.
#[derive(Debug, Clone, Default)]
pub struct DirectTransport;

impl Transport for DirectTransport {
    fn exchange(
        &mut self,
        _participants: &[usize],
        _root: Option<usize>,
        _bytes: usize,
    ) -> ExchangeOutcome {
        ExchangeOutcome::default()
    }

    fn take_deaths(&mut self, _phase: &str) -> Vec<usize> {
        Vec::new()
    }
}

/// Fault-injecting transport driven by a [`FaultPlan`].
#[derive(Debug, Clone)]
pub struct FaultTransport {
    plan: FaultPlan,
    rng: Pcg64,
    /// Scheduled deaths not yet drained.
    pending: Vec<(usize, String)>,
}

impl FaultTransport {
    pub fn new(plan: FaultPlan) -> FaultTransport {
        let rng = Pcg64::new(plan.seed, 0xFA);
        let pending = plan.deaths.clone();
        FaultTransport { plan, rng, pending }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

impl Transport for FaultTransport {
    fn exchange(
        &mut self,
        participants: &[usize],
        root: Option<usize>,
        _bytes: usize,
    ) -> ExchangeOutcome {
        let mut out = ExchangeOutcome::default();
        let straggling = self.plan.straggler_prob > 0.0
            && self.plan.straggler_delay_s > 0.0;
        for &id in participants {
            if straggling
                && self.rng.uniform() < self.plan.straggler_prob
            {
                out.straggles.push((id, self.plan.straggler_delay_s));
            }
            // The root of a rooted collective cannot drop its own
            // messages (it is the timeout detector); everyone else
            // runs the drop/retry loop.
            if self.plan.drop_prob > 0.0 && root != Some(id) {
                let mut attempt = 0usize;
                loop {
                    if self.rng.uniform() >= self.plan.drop_prob {
                        out.retries += attempt;
                        break;
                    }
                    // This attempt was dropped: the detector waits one
                    // (backed-off) timeout before resending.
                    out.timeouts += 1;
                    out.extra_delay_s += self.plan.timeout_s
                        * self.plan.backoff.powi(attempt as i32);
                    if attempt >= self.plan.max_retries {
                        out.failed.push(id);
                        break;
                    }
                    attempt += 1;
                }
            }
        }
        out
    }

    fn take_deaths(&mut self, phase: &str) -> Vec<usize> {
        let mut dead = Vec::new();
        self.pending.retain(|(id, ph)| {
            if ph == phase {
                dead.push(*id);
                false
            } else {
                true
            }
        });
        dead.sort_unstable();
        dead.dedup();
        dead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_transport_is_inert() {
        let mut t = DirectTransport;
        let out = t.exchange(&[0, 1, 2], Some(0), 1024);
        assert_eq!(out, ExchangeOutcome::default());
        assert!(t.take_deaths("predict").is_empty());
    }

    #[test]
    fn zero_plan_fault_transport_is_inert() {
        let mut t = FaultTransport::new(FaultPlan::seeded(99));
        for _ in 0..16 {
            let out = t.exchange(&[0, 1, 2, 3], None, 64);
            assert_eq!(out, ExchangeOutcome::default());
        }
        assert!(t.take_deaths("local_summary").is_empty());
    }

    #[test]
    fn same_seed_same_outcomes() {
        let plan = FaultPlan::seeded(7)
            .with_drops(0.4, 3)
            .with_stragglers(0.5, 1e-3);
        let mut a = FaultTransport::new(plan.clone());
        let mut b = FaultTransport::new(plan);
        for _ in 0..32 {
            assert_eq!(a.exchange(&[0, 1, 2, 3], Some(0), 8),
                       b.exchange(&[0, 1, 2, 3], Some(0), 8));
        }
    }

    #[test]
    fn certain_drop_exhausts_retries_except_root() {
        let plan = FaultPlan::seeded(1)
            .with_drops(1.0, 2)
            .with_timeout(1e-3, 2.0);
        let mut t = FaultTransport::new(plan);
        let out = t.exchange(&[0, 1, 2], Some(0), 8);
        // root 0 never rolls drops; 1 and 2 exhaust their retries
        assert_eq!(out.failed, vec![1, 2]);
        // 3 attempts each (initial + 2 retries), all dropped
        assert_eq!(out.timeouts, 6);
        // no successful resends
        assert_eq!(out.retries, 0);
        // backoff: per node 1e-3 * (1 + 2 + 4)
        let per_node = 1e-3 * (1.0 + 2.0 + 4.0);
        assert!((out.extra_delay_s - 2.0 * per_node).abs() < 1e-12);
    }

    #[test]
    fn rootless_exchange_rolls_everyone() {
        let plan = FaultPlan::seeded(1).with_drops(1.0, 0);
        let mut t = FaultTransport::new(plan);
        let out = t.exchange(&[0, 1], None, 8);
        assert_eq!(out.failed, vec![0, 1]);
    }

    #[test]
    fn scheduled_deaths_drain_once_by_phase() {
        let plan = FaultPlan::none()
            .kill(2, "predict")
            .kill(1, "local_summary")
            .kill(2, "predict"); // duplicate collapses
        let mut t = FaultTransport::new(plan);
        assert!(t.take_deaths("global_summary").is_empty());
        assert_eq!(t.take_deaths("local_summary"), vec![1]);
        assert_eq!(t.take_deaths("predict"), vec![2]);
        assert!(t.take_deaths("predict").is_empty());
    }

    #[test]
    fn straggles_are_deterministic_and_counted() {
        let plan = FaultPlan::seeded(3).with_stragglers(1.0, 5e-4);
        let mut t = FaultTransport::new(plan);
        let out = t.exchange(&[4, 7], None, 8);
        assert_eq!(out.straggles, vec![(4, 5e-4), (7, 5e-4)]);
        assert!(out.failed.is_empty());
    }
}
