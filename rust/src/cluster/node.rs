//! Virtual cluster node: an id and a monotone clock.

/// One simulated machine. The clock is in seconds since run start and
/// only ever moves forward.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: usize,
    clock: f64,
    /// cumulative compute seconds (excludes waiting on communication)
    compute_total: f64,
    /// false once the fault transport declares this machine dead
    alive: bool,
}

impl Node {
    pub fn new(id: usize) -> Node {
        Node { id, clock: 0.0, compute_total: 0.0, alive: true }
    }

    pub fn alive(&self) -> bool {
        self.alive
    }

    /// Mark the machine dead; its clock freezes and it leaves every
    /// subsequent collective.
    pub fn kill(&mut self) {
        self.alive = false;
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn compute_total(&self) -> f64 {
        self.compute_total
    }

    /// Advance the clock by `secs` of local compute.
    pub fn advance_compute(&mut self, secs: f64) {
        assert!(secs >= 0.0, "negative compute time");
        self.clock += secs;
        self.compute_total += secs;
    }

    /// Wait until at least `t` (communication arrival / barrier).
    pub fn wait_until(&mut self, t: f64) {
        if t > self.clock {
            self.clock = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_monotone() {
        let mut n = Node::new(0);
        n.advance_compute(1.5);
        assert_eq!(n.clock(), 1.5);
        n.wait_until(1.0); // in the past: no-op
        assert_eq!(n.clock(), 1.5);
        n.wait_until(2.0);
        assert_eq!(n.clock(), 2.0);
        assert_eq!(n.compute_total(), 1.5);
    }

    #[test]
    fn kill_flips_alive() {
        let mut n = Node::new(3);
        assert!(n.alive());
        n.kill();
        assert!(!n.alive());
    }

    #[test]
    #[should_panic]
    fn negative_compute_rejected() {
        Node::new(0).advance_compute(-1.0);
    }
}
