//! Marginal-likelihood hyperparameter learning (Section 6: "learned
//! using randomly selected data of size 10000 via maximum likelihood").
//!
//! Exact GP negative log marginal likelihood (NLML) and its analytic
//! gradient w.r.t. the log-hyperparameters, optimized with Adam
//! ([`crate::train::optim`]) on a random subset (the paper's procedure,
//! at our scale). For training on *all* the data with the PITC low-rank
//! model distributed across the cluster, see [`crate::train`].
//!
//! # The blocked gradient path
//!
//! The seed computed `0.5·tr(K⁻¹dK_p) − 0.5·αᵀdK_pα` with O(n²) scalar
//! double-loops per hyperparameter against a separately materialized
//! K⁻¹. [`nlml_and_grad`] now folds both terms into one workspace
//! `W = K⁻¹ − ααᵀ` (blocked solve + rank-1 update; K⁻¹ is never held on
//! its own) and evaluates every `0.5·dot(W, dK_p)` through the
//! ‖x‖²-expansion trick ([`SeArd::grad_dots`]) — no per-hyper dK matrix
//! is materialized and the per-hyper cost drops to one matvec. The seed
//! implementation survives as
//! [`nlml_and_grad_scalar`], the property-tested reference
//! (`blocked_gradient_matches_scalar_reference`).

use crate::kernel::SeArd;
use crate::linalg::cholesky::logdet_from_chol;
use crate::linalg::{
    cho_solve_mat, cho_solve_mat_ctx, cho_solve_vec, cholesky,
    cholesky_blocked, dot, LinalgCtx, Mat,
};
use crate::train::optim::{minimize, AdamConfig};
use crate::util::Pcg64;

/// NLML = 0.5·yᵀK⁻¹y + 0.5·log|K| + n/2·log 2π  (y centered by caller).
/// Returns (value, gradient in to_vec() layout).
pub fn nlml_and_grad(hyp: &SeArd, x: &Mat, y: &[f64]) -> (f64, Vec<f64>) {
    nlml_and_grad_ctx(&LinalgCtx::serial(), hyp, x, y)
}

/// [`nlml_and_grad`] with explicit linalg execution context: Gram,
/// Cholesky and the W-solve run on the blocked (optionally pooled)
/// engine; gradients use the expansion trick (see module docs).
pub fn nlml_and_grad_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    x: &Mat,
    y: &[f64],
) -> (f64, Vec<f64>) {
    let n = x.rows;
    assert_eq!(y.len(), n);
    let d = hyp.dim();
    let k0 = hyp.gram_ctx(lctx, x, x); // noise-free
    let mut kj = k0.clone();
    kj.add_diag(hyp.sn2() + hyp.jitter());
    let l = cholesky_blocked(lctx, &kj).expect("K not SPD in NLML");
    let alpha = cho_solve_vec(&l, y);
    let logdet = logdet_from_chol(&l);
    let value = 0.5 * dot(y, &alpha)
        + 0.5 * logdet
        + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // grad_p = 0.5·dot(W, dK_p) with W = K⁻¹ − ααᵀ: the trace and the
    // quadratic term share one workspace.
    let mut w = cho_solve_mat_ctx(lctx, &l, &Mat::identity(n));
    for i in 0..n {
        for j in 0..n {
            w[(i, j)] -= alpha[i] * alpha[j];
        }
    }
    // ls/sf2 slots via the expansion trick on the noise-free block
    // (`same = false` keeps the seed's convention of ignoring the
    // jitter's sf2-dependence — a ≤1e-8-relative effect); the sn2 slot
    // is 0.5·sn2·tr(W) directly.
    let mut grad = hyp.grad_dots(&w, &k0, x, x, false);
    for g in grad.iter_mut() {
        *g *= 0.5;
    }
    let tr_w: f64 = (0..n).map(|i| w[(i, i)]).sum();
    grad[d + 1] = 0.5 * hyp.sn2() * tr_w;
    (value, grad)
}

/// The seed implementation — O(n²) scalar trace/quadratic loops per
/// hyperparameter against a materialized K⁻¹. Kept verbatim as the
/// reference for the blocked path.
pub fn nlml_and_grad_scalar(hyp: &SeArd, x: &Mat, y: &[f64]) -> (f64, Vec<f64>) {
    let n = x.rows;
    assert_eq!(y.len(), n);
    let (k, grads) = hyp.gram_with_grads(x, x, true);
    let mut kj = k;
    kj.add_diag(hyp.jitter());
    let l = cholesky(&kj).expect("K not SPD in NLML");
    let alpha = cho_solve_vec(&l, y);
    let logdet = logdet_from_chol(&l);
    let quad: f64 = y.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
    let value = 0.5 * quad
        + 0.5 * logdet
        + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // dNLML/dθ = 0.5·tr(K⁻¹ dK) − 0.5·αᵀ dK α
    let kinv = cho_solve_mat(&l, &Mat::identity(n));
    let grad = grads
        .iter()
        .map(|dk| {
            let mut tr = 0.0;
            for i in 0..n {
                for j in 0..n {
                    tr += kinv[(i, j)] * dk[(j, i)];
                }
            }
            let mut quad_g = 0.0;
            for i in 0..n {
                for j in 0..n {
                    quad_g += alpha[i] * dk[(i, j)] * alpha[j];
                }
            }
            0.5 * tr - 0.5 * quad_g
        })
        .collect();
    (value, grad)
}

/// Adam optimizer configuration for MLE.
#[derive(Debug, Clone)]
pub struct MleConfig {
    pub iters: usize,
    pub lr: f64,
    /// subset size for the likelihood (paper: 10000; scale down here)
    pub subset: usize,
    pub seed: u64,
    /// clamp on log-hyperparameters to keep K numerically sane
    pub log_bound: f64,
}

impl Default for MleConfig {
    fn default() -> Self {
        MleConfig { iters: 60, lr: 0.08, subset: 256, seed: 7, log_bound: 6.0 }
    }
}

/// Result of hyperparameter learning.
#[derive(Debug, Clone)]
pub struct MleResult {
    pub hyp: SeArd,
    pub nlml_trace: Vec<f64>,
}

/// Learn hyperparameters by Adam on the exact NLML of a random subset.
/// The loop is [`crate::train::optim::minimize`] — the same Adam the
/// distributed trainer uses — producing the identical iterate sequence
/// as the seed's hand-rolled loop (plus one trailing evaluation so the
/// trace ends at the final θ).
pub fn learn_hyperparameters(
    init: &SeArd,
    x: &Mat,
    y: &[f64],
    cfg: &MleConfig,
) -> MleResult {
    let mut rng = Pcg64::new(cfg.seed, 0x41);
    let n_sub = cfg.subset.min(x.rows);
    let idx = rng.sample_indices(x.rows, n_sub);
    let xs = x.select_rows(&idx);
    let ys_raw: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
    let mean = ys_raw.iter().sum::<f64>() / n_sub as f64;
    let ys: Vec<f64> = ys_raw.iter().map(|v| v - mean).collect();

    let adam = AdamConfig {
        iters: cfg.iters,
        lr: cfg.lr,
        log_bound: cfg.log_bound,
        ..Default::default()
    };
    let result = minimize(&adam, &init.to_vec(), |theta| {
        nlml_and_grad(&SeArd::from_vec(theta), &xs, &ys)
    });
    MleResult {
        hyp: SeArd::from_vec(&result.theta),
        nlml_trace: result.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Pcg64::seed(2);
        let n = 10;
        let hyp = SeArd {
            log_ls: vec![0.2, -0.1],
            log_sf2: 0.3,
            log_sn2: -1.5,
        };
        let x = Mat::from_vec(n, 2, rng.normals(n * 2));
        let y = rng.normals(n);
        let (_, grad) = nlml_and_grad(&hyp, &x, &y);
        let theta = hyp.to_vec();
        let eps = 1e-6;
        for p in 0..theta.len() {
            let mut tp = theta.clone();
            tp[p] += eps;
            let mut tm = theta.clone();
            tm[p] -= eps;
            let (vp, _) = nlml_and_grad(&SeArd::from_vec(&tp), &x, &y);
            let (vm, _) = nlml_and_grad(&SeArd::from_vec(&tm), &x, &y);
            let fd = (vp - vm) / (2.0 * eps);
            assert_close(grad[p], fd, 1e-4, 1e-5);
        }
    }

    /// The blocked path (W-workspace + expansion trick) computes the
    /// same value and gradient as the seed scalar reference.
    #[test]
    fn blocked_gradient_matches_scalar_reference() {
        use crate::testkit::prop::prop_check;
        prop_check("nlml-blocked-vs-scalar", 10, |g| {
            let d = g.usize_in(1, 4);
            let n = g.usize_in(2, 24);
            let hyp = SeArd {
                log_ls: g.uniform_vec(d, -0.6, 0.6),
                log_sf2: g.f64_in(-0.5, 0.5),
                log_sn2: g.f64_in(-3.0, -1.0),
            };
            let x = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let (v_b, g_b) = nlml_and_grad(&hyp, &x, &y);
            let (v_s, g_s) = nlml_and_grad_scalar(&hyp, &x, &y);
            assert_close(v_b, v_s, 1e-10, 1e-10);
            for (a, b) in g_b.iter().zip(g_s.iter()) {
                assert_close(*a, *b, 1e-8, 1e-8);
            }
        });
    }

    /// Pooled evaluation is bitwise-identical to serial.
    #[test]
    fn nlml_pooled_equals_serial() {
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        let mut rng = Pcg64::seed(31);
        let (n, d) = (30, 3);
        let hyp = SeArd::isotropic(d, 1.1, 0.9, 0.1);
        let x = Mat::from_vec(n, d, rng.normals(n * d));
        let y = rng.normals(n);
        let serial = nlml_and_grad(&hyp, &x, &y);
        let ctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
        let pooled = nlml_and_grad_ctx(&ctx, &hyp, &x, &y);
        assert_eq!(serial.0.to_bits(), pooled.0.to_bits());
        assert_eq!(serial.1, pooled.1);
    }

    #[test]
    fn nlml_lower_for_true_hyperparameters() {
        // data drawn (via RFF) from a GP with known hyp: NLML at the true
        // hyp must beat NLML at a far-off hyp.
        let truth = SeArd::isotropic(1, 0.7, 1.0, 0.01);
        let mut rng = Pcg64::seed(5);
        let f = crate::data::rff::RffSampler::draw(&truth, 256, &mut rng);
        let n = 60;
        let x = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.1 - 3.0).collect());
        let y: Vec<f64> = (0..n)
            .map(|i| f.eval(x.row(i)) + 0.1 * rng.normal())
            .collect();
        let mean = y.iter().sum::<f64>() / n as f64;
        let yc: Vec<f64> = y.iter().map(|v| v - mean).collect();
        let (good, _) = nlml_and_grad(&truth, &x, &yc);
        let bad_hyp = SeArd::isotropic(1, 20.0, 0.01, 1.0);
        let (bad, _) = nlml_and_grad(&bad_hyp, &x, &yc);
        assert!(good < bad, "good {good} vs bad {bad}");
    }

    #[test]
    fn adam_decreases_nlml() {
        let truth = SeArd::isotropic(1, 0.5, 1.5, 0.05);
        let mut rng = Pcg64::seed(9);
        let f = crate::data::rff::RffSampler::draw(&truth, 256, &mut rng);
        let n = 80;
        let x = Mat::from_vec(n, 1, (0..n).map(|_| rng.uniform_in(-3.0, 3.0)).collect());
        let y: Vec<f64> = (0..n)
            .map(|i| f.eval(x.row(i)) + 0.2 * rng.normal())
            .collect();
        let init = SeArd::isotropic(1, 2.0, 0.5, 0.5);
        let cfg = MleConfig { iters: 40, subset: 80, ..Default::default() };
        let result = learn_hyperparameters(&init, &x, &y, &cfg);
        let first = result.nlml_trace[0];
        let last = *result.nlml_trace.last().unwrap();
        assert!(last < first - 1.0, "no progress: {first} -> {last}");
        // learned noise should be closer to truth than the bad init
        let learned_sn2 = result.hyp.sn2();
        assert!(learned_sn2 < 0.4, "sn2 {learned_sn2}");
    }

    #[test]
    fn respects_log_bounds() {
        let mut rng = Pcg64::seed(11);
        let x = Mat::from_vec(12, 1, rng.normals(12));
        let y = rng.normals(12);
        let init = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let cfg = MleConfig { iters: 10, subset: 12, log_bound: 0.5, lr: 5.0,
                              ..Default::default() };
        let r = learn_hyperparameters(&init, &x, &y, &cfg);
        for v in r.hyp.to_vec() {
            assert!(v.abs() <= 0.5 + 1e-12);
        }
    }
}
