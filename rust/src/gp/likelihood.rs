//! Marginal-likelihood hyperparameter learning (Section 6: "learned
//! using randomly selected data of size 10000 via maximum likelihood").
//!
//! Exact GP negative log marginal likelihood (NLML) and its analytic
//! gradient w.r.t. the log-hyperparameters, optimized with Adam on a
//! random subset (the paper's procedure, at our scale).

use crate::kernel::SeArd;
use crate::linalg::{cho_solve_mat, cho_solve_vec, cholesky, Mat};
use crate::util::Pcg64;

/// NLML = 0.5·yᵀK⁻¹y + 0.5·log|K| + n/2·log 2π  (y centered by caller).
/// Returns (value, gradient in to_vec() layout).
pub fn nlml_and_grad(hyp: &SeArd, x: &Mat, y: &[f64]) -> (f64, Vec<f64>) {
    let n = x.rows;
    assert_eq!(y.len(), n);
    let (k, grads) = hyp.gram_with_grads(x, x, true);
    let mut kj = k;
    kj.add_diag(hyp.jitter());
    let l = cholesky(&kj).expect("K not SPD in NLML");
    let alpha = cho_solve_vec(&l, y);
    let logdet = crate::linalg::cholesky::logdet_from_chol(&l);
    let quad: f64 = y.iter().zip(alpha.iter()).map(|(a, b)| a * b).sum();
    let value = 0.5 * quad
        + 0.5 * logdet
        + 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();

    // dNLML/dθ = 0.5·tr(K⁻¹ dK) − 0.5·αᵀ dK α
    let kinv = cho_solve_mat(&l, &Mat::identity(n));
    let grad = grads
        .iter()
        .map(|dk| {
            let mut tr = 0.0;
            for i in 0..n {
                for j in 0..n {
                    tr += kinv[(i, j)] * dk[(j, i)];
                }
            }
            let mut quad_g = 0.0;
            for i in 0..n {
                for j in 0..n {
                    quad_g += alpha[i] * dk[(i, j)] * alpha[j];
                }
            }
            0.5 * tr - 0.5 * quad_g
        })
        .collect();
    (value, grad)
}

/// Adam optimizer configuration for MLE.
#[derive(Debug, Clone)]
pub struct MleConfig {
    pub iters: usize,
    pub lr: f64,
    /// subset size for the likelihood (paper: 10000; scale down here)
    pub subset: usize,
    pub seed: u64,
    /// clamp on log-hyperparameters to keep K numerically sane
    pub log_bound: f64,
}

impl Default for MleConfig {
    fn default() -> Self {
        MleConfig { iters: 60, lr: 0.08, subset: 256, seed: 7, log_bound: 6.0 }
    }
}

/// Result of hyperparameter learning.
#[derive(Debug, Clone)]
pub struct MleResult {
    pub hyp: SeArd,
    pub nlml_trace: Vec<f64>,
}

/// Learn hyperparameters by Adam on the exact NLML of a random subset.
pub fn learn_hyperparameters(
    init: &SeArd,
    x: &Mat,
    y: &[f64],
    cfg: &MleConfig,
) -> MleResult {
    let mut rng = Pcg64::new(cfg.seed, 0x41);
    let n_sub = cfg.subset.min(x.rows);
    let idx = rng.sample_indices(x.rows, n_sub);
    let xs = x.select_rows(&idx);
    let ys_raw: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
    let mean = ys_raw.iter().sum::<f64>() / n_sub as f64;
    let ys: Vec<f64> = ys_raw.iter().map(|v| v - mean).collect();

    let mut theta = init.to_vec();
    let p = theta.len();
    let (mut m1, mut m2) = (vec![0.0; p], vec![0.0; p]);
    let (b1, b2, eps) = (0.9, 0.999, 1e-8);
    let mut trace = Vec::with_capacity(cfg.iters);

    for t in 1..=cfg.iters {
        let hyp = SeArd::from_vec(&theta);
        let (value, grad) = nlml_and_grad(&hyp, &xs, &ys);
        trace.push(value);
        for i in 0..p {
            m1[i] = b1 * m1[i] + (1.0 - b1) * grad[i];
            m2[i] = b2 * m2[i] + (1.0 - b2) * grad[i] * grad[i];
            let mh = m1[i] / (1.0 - b1.powi(t as i32));
            let vh = m2[i] / (1.0 - b2.powi(t as i32));
            theta[i] -= cfg.lr * mh / (vh.sqrt() + eps);
            theta[i] = theta[i].clamp(-cfg.log_bound, cfg.log_bound);
        }
    }
    MleResult { hyp: SeArd::from_vec(&theta), nlml_trace: trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::assert_close;

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Pcg64::seed(2);
        let n = 10;
        let hyp = SeArd {
            log_ls: vec![0.2, -0.1],
            log_sf2: 0.3,
            log_sn2: -1.5,
        };
        let x = Mat::from_vec(n, 2, rng.normals(n * 2));
        let y = rng.normals(n);
        let (_, grad) = nlml_and_grad(&hyp, &x, &y);
        let theta = hyp.to_vec();
        let eps = 1e-6;
        for p in 0..theta.len() {
            let mut tp = theta.clone();
            tp[p] += eps;
            let mut tm = theta.clone();
            tm[p] -= eps;
            let (vp, _) = nlml_and_grad(&SeArd::from_vec(&tp), &x, &y);
            let (vm, _) = nlml_and_grad(&SeArd::from_vec(&tm), &x, &y);
            let fd = (vp - vm) / (2.0 * eps);
            assert_close(grad[p], fd, 1e-4, 1e-5);
        }
    }

    #[test]
    fn nlml_lower_for_true_hyperparameters() {
        // data drawn (via RFF) from a GP with known hyp: NLML at the true
        // hyp must beat NLML at a far-off hyp.
        let truth = SeArd::isotropic(1, 0.7, 1.0, 0.01);
        let mut rng = Pcg64::seed(5);
        let f = crate::data::rff::RffSampler::draw(&truth, 256, &mut rng);
        let n = 60;
        let x = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.1 - 3.0).collect());
        let y: Vec<f64> = (0..n)
            .map(|i| f.eval(x.row(i)) + 0.1 * rng.normal())
            .collect();
        let mean = y.iter().sum::<f64>() / n as f64;
        let yc: Vec<f64> = y.iter().map(|v| v - mean).collect();
        let (good, _) = nlml_and_grad(&truth, &x, &yc);
        let bad_hyp = SeArd::isotropic(1, 20.0, 0.01, 1.0);
        let (bad, _) = nlml_and_grad(&bad_hyp, &x, &yc);
        assert!(good < bad, "good {good} vs bad {bad}");
    }

    #[test]
    fn adam_decreases_nlml() {
        let truth = SeArd::isotropic(1, 0.5, 1.5, 0.05);
        let mut rng = Pcg64::seed(9);
        let f = crate::data::rff::RffSampler::draw(&truth, 256, &mut rng);
        let n = 80;
        let x = Mat::from_vec(n, 1, (0..n).map(|_| rng.uniform_in(-3.0, 3.0)).collect());
        let y: Vec<f64> = (0..n)
            .map(|i| f.eval(x.row(i)) + 0.2 * rng.normal())
            .collect();
        let init = SeArd::isotropic(1, 2.0, 0.5, 0.5);
        let cfg = MleConfig { iters: 40, subset: 80, ..Default::default() };
        let result = learn_hyperparameters(&init, &x, &y, &cfg);
        let first = result.nlml_trace[0];
        let last = *result.nlml_trace.last().unwrap();
        assert!(last < first - 1.0, "no progress: {first} -> {last}");
        // learned noise should be closer to truth than the bad init
        let learned_sn2 = result.hyp.sn2();
        assert!(learned_sn2 < 0.4, "sn2 {learned_sn2}");
    }

    #[test]
    fn respects_log_bounds() {
        let mut rng = Pcg64::seed(11);
        let x = Mat::from_vec(12, 1, rng.normals(12));
        let y = rng.normals(12);
        let init = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let cfg = MleConfig { iters: 10, subset: 12, log_bound: 0.5, lr: 5.0,
                              ..Default::default() };
        let r = learn_hyperparameters(&init, &x, &y, &cfg);
        for v in r.hyp.to_vec() {
            assert!(v.abs() <= 0.5 + 1e-12);
        }
    }
}
