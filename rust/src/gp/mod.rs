//! Gaussian process regression: the exact FGP baseline, the centralized
//! low-rank approximations (PITC, PIC, ICF-based GP), support-set
//! selection, and marginal-likelihood hyperparameter learning.
//!
//! The *parallel* versions (pPITC/pPIC/pICF) live in [`crate::parallel`];
//! they reuse the block math in [`summaries`], which mirrors the AOT
//! graphs in `python/compile/model.py` constant-for-constant so native
//! and PJRT execution agree numerically.

pub mod fgp;
pub mod icf_gp;
pub mod likelihood;
pub mod pic;
pub mod pitc;
pub mod predictor;
pub mod summaries;
pub mod support;

pub use fgp::FullGp;
pub use predictor::{OpScratch, PredictOperator};

/// A predictive Gaussian marginal per test point: mean + variance.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
}

impl Prediction {
    pub fn empty() -> Prediction {
        Prediction { mean: Vec::new(), var: Vec::new() }
    }

    #[must_use]
    pub fn len(&self) -> usize {
        self.mean.len()
    }

    #[must_use]
    pub fn is_empty(&self) -> bool {
        self.mean.is_empty()
    }

    /// Concatenate block predictions in order.
    pub fn concat(blocks: Vec<Prediction>) -> Prediction {
        let mut out = Prediction::empty();
        for b in blocks {
            out.mean.extend(b.mean);
            out.var.extend(b.var);
        }
        out
    }

    /// Scatter block predictions back to original positions: `idx[k]`
    /// lists the global row of each entry in `blocks[k]`.
    ///
    /// # Contract
    ///
    /// The index lists must cover `0..n` **exactly once** in total
    /// (Definition 1 test partitions do). Rows never referenced would
    /// silently stay at `0.0` — so coverage is checked with debug
    /// assertions; the typed-validation path for untrusted partitions
    /// is `api::PredictSpec::with_blocks`.
    pub fn scatter(blocks: &[Prediction], idx: &[Vec<usize>], n: usize) -> Prediction {
        let mut mean = vec![0.0; n];
        let mut var = vec![0.0; n];
        #[cfg(debug_assertions)]
        let mut seen = vec![false; n];
        for (b, block_idx) in blocks.iter().zip(idx.iter()) {
            assert_eq!(b.len(), block_idx.len());
            for (k, &g) in block_idx.iter().enumerate() {
                #[cfg(debug_assertions)]
                {
                    debug_assert!(!seen[g], "scatter: row {g} assigned twice");
                    seen[g] = true;
                }
                mean[g] = b.mean[k];
                var[g] = b.var[k];
            }
        }
        #[cfg(debug_assertions)]
        debug_assert!(seen.iter().all(|&s| s),
                      "scatter: idx must cover 0..{n} exactly once");
        Prediction { mean, var }
    }

    /// Shift means by a constant (un-centering).
    pub fn shift_mean(&mut self, delta: f64) {
        for m in self.mean.iter_mut() {
            *m += delta;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_and_scatter() {
        let a = Prediction { mean: vec![1.0, 2.0], var: vec![0.1, 0.2] };
        let b = Prediction { mean: vec![3.0], var: vec![0.3] };
        let c = Prediction::concat(vec![a.clone(), b.clone()]);
        assert_eq!(c.mean, vec![1.0, 2.0, 3.0]);

        let s = Prediction::scatter(&[a, b], &[vec![2, 0], vec![1]], 3);
        assert_eq!(s.mean, vec![2.0, 3.0, 1.0]);
        assert_eq!(s.var, vec![0.2, 0.3, 0.1]);
    }

    /// The scatter contract: every row of `0..n` must be assigned.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "scatter")]
    fn scatter_rejects_partial_coverage() {
        let a = Prediction { mean: vec![1.0], var: vec![0.1] };
        let _ = Prediction::scatter(&[a], &[vec![2]], 3); // rows 0,1 missing
    }

    /// Duplicate assignments are also a contract violation.
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "assigned twice")]
    fn scatter_rejects_duplicates() {
        let a = Prediction { mean: vec![1.0, 2.0], var: vec![0.1, 0.2] };
        let _ = Prediction::scatter(&[a], &[vec![0, 0]], 2);
    }

    #[test]
    fn shift_mean() {
        let mut p = Prediction { mean: vec![1.0, -1.0], var: vec![0.0, 0.0] };
        p.shift_mean(10.0);
        assert_eq!(p.mean, vec![11.0, 9.0]);
    }
}
