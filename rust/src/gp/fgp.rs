//! FGP — the exact full Gaussian process (Section 2), the paper's
//! baseline: cubic-time fit, all-data predictions via eqs. (1)-(2).

use std::sync::OnceLock;

use super::predictor::{fgp_operator, PredictOperator};
use super::Prediction;
use crate::kernel::SeArd;
use crate::linalg::{cho_solve_vec, cholesky_blocked, matvec,
                    solve_lower_mat_ctx, LinalgCtx, Mat};

/// An exact GP regressor fitted on `(X_D, y_D)`.
#[derive(Debug, Clone)]
pub struct FullGp {
    hyp: SeArd,
    xd: Mat,
    /// chol(Σ_DD + jitter)
    l: Mat,
    /// α = Σ_DD⁻¹ (y − μ)
    alpha: Vec<f64>,
    /// prior mean (empirical train mean)
    pub y_mean: f64,
    /// Serve-path operator (`A = Σ_DD⁻¹`), built lazily on first
    /// [`FullGp::predictor`] call: the O(n³) explicit inverse is only
    /// worth paying when many batches will amortize it, so one-shot
    /// sweep predictions never do.
    op: OnceLock<PredictOperator>,
}

impl FullGp {
    /// Fit: one O(n³) Cholesky of Σ_DD (serial ctx).
    pub fn fit(hyp: &SeArd, xd: &Mat, y: &[f64]) -> FullGp {
        FullGp::fit_ctx(&LinalgCtx::serial(), hyp, xd, y)
    }

    /// [`FullGp::fit`] with explicit linalg execution context: the
    /// Gram build and the n³ Cholesky run blocked and (optionally)
    /// thread-parallel — the baseline's entire fit cost.
    pub fn fit_ctx(lctx: &LinalgCtx, hyp: &SeArd, xd: &Mat, y: &[f64])
        -> FullGp
    {
        FullGp::try_fit_ctx(lctx, hyp, xd, y)
            .unwrap_or_else(|e| panic!("Σ_DD not SPD: {e}"))
    }

    /// Fallible [`FullGp::fit_ctx`] — the facade ([`crate::api`])
    /// reports a non-SPD Σ_DD as a typed error instead of panicking.
    pub fn try_fit_ctx(lctx: &LinalgCtx, hyp: &SeArd, xd: &Mat, y: &[f64])
        -> Result<FullGp, crate::linalg::cholesky::NotSpd>
    {
        assert_eq!(xd.rows, y.len());
        let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
        let centered: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
        let sigma = hyp.cov_same_ctx(lctx, xd, true);
        let l = cholesky_blocked(lctx, &sigma)?;
        let alpha = cho_solve_vec(&l, &centered);
        Ok(FullGp {
            hyp: hyp.clone(),
            xd: xd.clone(),
            l,
            alpha,
            y_mean,
            op: OnceLock::new(),
        })
    }

    pub fn n_train(&self) -> usize {
        self.xd.rows
    }

    /// The staged predictive operator (built on first call, cached):
    /// mean = K_UD·α as one GEMV, variance through the fused
    /// `diag(G·Σ_DD⁻¹·Gᵀ)` kernel instead of a per-batch triangular
    /// solve. Equal to [`FullGp::predict`] ≤1e-12 (tested).
    pub fn predictor(&self, lctx: &LinalgCtx) -> &PredictOperator {
        self.op.get_or_init(|| {
            fgp_operator(lctx, &self.hyp, &self.xd, &self.l, &self.alpha,
                         self.y_mean)
        })
    }

    /// Serve-path prediction through [`FullGp::predictor`].
    pub fn predict_fast_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        self.predictor(lctx).predict_ctx(lctx, xu)
    }

    /// Predict eqs. (1)-(2) (diagonal covariance), serial ctx.
    pub fn predict(&self, xu: &Mat) -> Prediction {
        self.predict_ctx(&LinalgCtx::serial(), xu)
    }

    /// [`FullGp::predict`] with explicit linalg execution context.
    pub fn predict_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        let k_ud = self.hyp.cov_cross_ctx(lctx, xu, &self.xd); // (U, n)
        let mut mean = matvec(&k_ud, &self.alpha);
        for m in mean.iter_mut() {
            *m += self.y_mean;
        }
        // diag(K_ud Σ⁻¹ K_du) via W = L⁻¹ K_du
        let w = solve_lower_mat_ctx(lctx, &self.l, &k_ud.transpose()); // (n, U)
        let prior = self.hyp.prior_var();
        let var = (0..xu.rows)
            .map(|i| {
                let t: f64 =
                    (0..self.xd.rows).map(|r| w[(r, i)] * w[(r, i)]).sum();
                prior - t
            })
            .collect();
        Prediction { mean, var }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::cholesky;
    use crate::testkit::prop::prop_check;
    use crate::util::Pcg64;

    fn hyp1d() -> SeArd {
        SeArd::isotropic(1, 0.8, 1.0, 1e-3)
    }

    #[test]
    fn interpolates_training_data() {
        // tiny noise → predictions at training inputs ≈ training outputs
        let hyp = hyp1d();
        let xd = Mat::from_vec(8, 1, (0..8).map(|i| i as f64 * 0.5).collect());
        let y: Vec<f64> = (0..8).map(|i| (i as f64 * 0.5).sin() + 2.0).collect();
        let gp = FullGp::fit(&hyp, &xd, &y);
        let pred = gp.predict(&xd);
        for i in 0..8 {
            assert!((pred.mean[i] - y[i]).abs() < 0.05, "i={i}");
            // posterior variance at observed points ≈ noise level
            assert!(pred.var[i] < 0.1);
        }
    }

    #[test]
    fn reverts_to_prior_far_away() {
        let hyp = hyp1d();
        let xd = Mat::from_vec(5, 1, (0..5).map(|i| i as f64 * 0.3).collect());
        let y = vec![5.0, 5.5, 6.0, 5.5, 5.0];
        let gp = FullGp::fit(&hyp, &xd, &y);
        let far = Mat::from_vec(1, 1, vec![100.0]);
        let pred = gp.predict(&far);
        // mean reverts to the train mean, variance to the prior
        assert!((pred.mean[0] - gp.y_mean).abs() < 1e-6);
        assert!((pred.var[0] - hyp.prior_var()).abs() < 1e-6);
    }

    #[test]
    fn variance_shrinks_near_data() {
        let hyp = hyp1d();
        let mut rng = Pcg64::seed(3);
        let xd = Mat::from_vec(10, 1, (0..10).map(|_| rng.uniform_in(-2.0, 2.0)).collect());
        let y = rng.normals(10);
        let gp = FullGp::fit(&hyp, &xd, &y);
        let near = Mat::from_vec(1, 1, vec![xd[(0, 0)] + 0.01]);
        let far = Mat::from_vec(1, 1, vec![50.0]);
        assert!(gp.predict(&near).var[0] < gp.predict(&far).var[0]);
    }

    #[test]
    fn posterior_variance_bounded_by_prior() {
        prop_check("fgp-var-bounds", 8, |g| {
            let n = g.usize_in(2, 12);
            let d = g.usize_in(1, 3);
            let hyp = SeArd {
                log_ls: g.uniform_vec(d, -0.5, 0.5),
                log_sf2: g.f64_in(-0.5, 0.5),
                log_sn2: g.f64_in(-3.0, -1.0),
            };
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let gp = FullGp::fit(&hyp, &xd, &y);
            let xu = Mat::from_vec(4, d, g.uniform_vec(4 * d, -3.0, 3.0));
            let pred = gp.predict(&xu);
            for &v in &pred.var {
                assert!(v > 0.0 && v <= hyp.prior_var() + 1e-9);
            }
        });
    }

    /// Pooled fit/predict reproduce the serial path bitwise (the
    /// engine's banding guarantee surfaced at the GP level).
    #[test]
    fn pooled_fit_predict_bitwise_matches_serial() {
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        let hyp = hyp1d();
        let mut rng = Pcg64::seed(17);
        let n = 120;
        let xd = Mat::from_vec(n, 1, rng.normals(n));
        let y = rng.normals(n);
        let xu = Mat::from_vec(9, 1, rng.normals(9));
        let serial = FullGp::fit(&hyp, &xd, &y);
        let want = serial.predict(&xu);
        let lctx = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
        let pooled = FullGp::fit_ctx(&lctx, &hyp, &xd, &y);
        let got = pooled.predict_ctx(&lctx, &xu);
        assert_eq!(want.mean, got.mean);
        assert_eq!(want.var, got.var);
    }

    /// The staged operator path reproduces the seed solve-based
    /// predict to ≤1e-12 (the serve-path equivalence contract).
    #[test]
    fn fast_path_matches_solve_path() {
        prop_check("fgp-fast-vs-solve", 8, |g| {
            let n = g.usize_in(2, 40);
            let d = g.usize_in(1, 3);
            let hyp = SeArd {
                log_ls: g.uniform_vec(d, -0.5, 0.5),
                log_sf2: g.f64_in(-0.5, 0.5),
                log_sn2: g.f64_in(-3.0, -1.0),
            };
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let gp = FullGp::fit(&hyp, &xd, &y);
            let xu = Mat::from_vec(6, d, g.uniform_vec(6 * d, -3.0, 3.0));
            let want = gp.predict(&xu);
            let got = gp.predict_fast_ctx(&LinalgCtx::serial(), &xu);
            crate::testkit::assert_all_close(&got.mean, &want.mean,
                                             1e-12, 1e-12);
            crate::testkit::assert_all_close(&got.var, &want.var,
                                             1e-12, 1e-12);
        });
    }

    #[test]
    fn mean_is_exact_gp_solve() {
        // verify against the direct formula μ = K_ud (K_dd+sn2 I)⁻¹ y
        let hyp = hyp1d();
        let xd = Mat::from_vec(6, 1, vec![0.0, 0.3, 0.9, 1.4, 2.0, 2.7]);
        let y = vec![1.0, 0.5, -0.2, 0.1, 0.8, 1.5];
        let gp = FullGp::fit(&hyp, &xd, &y);
        let xu = Mat::from_vec(2, 1, vec![0.5, 1.7]);
        let pred = gp.predict(&xu);

        let mean_y = y.iter().sum::<f64>() / 6.0;
        let centered: Vec<f64> = y.iter().map(|v| v - mean_y).collect();
        let sigma = hyp.cov_same(&xd, true);
        let l = cholesky(&sigma).unwrap();
        let alpha = cho_solve_vec(&l, &centered);
        let k_ud = hyp.cov_cross(&xu, &xd);
        let want: Vec<f64> = matvec(&k_ud, &alpha)
            .iter()
            .map(|v| v + mean_y)
            .collect();
        crate::testkit::assert_all_close(&pred.mean, &want, 1e-12, 1e-12);
    }
}
