//! Block-level summary math shared by the centralized approximations and
//! the parallel protocols — the rust mirror of `python/compile/model.py`.
//!
//! Every function here corresponds 1:1 to an AOT graph (Definitions 2–8
//! of the paper), with identical jitter conventions, so the native
//! backend and the PJRT artifacts are interchangeable on the hot path.

use super::Prediction;
use crate::kernel::{SeArd, JITTER_SCALE};
use crate::linalg::{
    cho_solve_mat_ctx, cho_solve_vec, cholesky_blocked, gemm, gemm_tn,
    matvec, solve_lower_mat_ctx, LinalgCtx, Mat,
};

/// Machine m's local summary (Definition 2) plus the cached Cholesky
/// factor of `Σ_{D_m D_m | S}` reused by pPIC.
///
/// `PartialEq` is bitwise on the `f64` payloads — it exists for the
/// checkpoint layer ([`crate::store`]), where "equal" must mean
/// "serializes identically".
#[derive(Debug, Clone, PartialEq)]
pub struct LocalSummary {
    /// `ẏ_S^m` — eq. (3)
    pub y_dot: Vec<f64>,
    /// `Σ̇_SS^m` — eq. (4)
    pub s_dot: Mat,
    /// chol(Σ_{D_m D_m | S})
    pub l_m: Mat,
}

impl LocalSummary {
    /// Bytes a machine sends to the master (ẏ_S + Σ̇_SS): the paper's
    /// O(|S|²) message.
    pub fn message_bytes(&self) -> usize {
        (self.y_dot.len() + self.s_dot.data.len()) * std::mem::size_of::<f64>()
    }
}

/// The global summary (Definition 3): `(ÿ_S, Σ̈_SS)`.
#[derive(Debug, Clone, PartialEq)]
pub struct GlobalSummary {
    pub y: Vec<f64>,
    pub s: Mat,
}

impl GlobalSummary {
    pub fn message_bytes(&self) -> usize {
        (self.y.len() + self.s.data.len()) * std::mem::size_of::<f64>()
    }
}

/// Support-set context precomputed once and shared by all machines:
/// `Σ_SS` (with noise, paper-literal) and its Cholesky factor.
#[derive(Debug, Clone)]
pub struct SupportContext {
    pub xs: Mat,
    /// Σ_SS = K_SS + sn2·I (no jitter) — the term entering eq. (6).
    pub sigma_ss: Mat,
    /// chol(K_SS + sn2·I + jitter·I)
    pub l_ss: Mat,
}

impl SupportContext {
    pub fn new(hyp: &SeArd, xs: &Mat) -> SupportContext {
        SupportContext::new_ctx(&LinalgCtx::serial(), hyp, xs)
    }

    /// [`SupportContext::new`] with explicit linalg execution context
    /// (pooled Gram + blocked/pooled Cholesky).
    pub fn new_ctx(lctx: &LinalgCtx, hyp: &SeArd, xs: &Mat) -> SupportContext {
        SupportContext::try_new_ctx(lctx, hyp, xs)
            .unwrap_or_else(|e| panic!("Σ_SS not SPD: {e}"))
    }

    /// Fallible [`SupportContext::new_ctx`] — the facade
    /// ([`crate::api`]) reports a non-SPD Σ_SS as a typed error instead
    /// of panicking.
    pub fn try_new_ctx(lctx: &LinalgCtx, hyp: &SeArd, xs: &Mat)
        -> Result<SupportContext, crate::linalg::cholesky::NotSpd>
    {
        let sigma_ss = hyp.cov_same_ctx(lctx, xs, false);
        let for_chol = hyp.cov_same_ctx(lctx, xs, true);
        let l_ss = cholesky_blocked(lctx, &for_chol)?;
        Ok(SupportContext { xs: xs.clone(), sigma_ss, l_ss })
    }

    pub fn size(&self) -> usize {
        self.xs.rows
    }
}

/// Definition 2: build machine m's local summary from its block.
/// Mirror of the `local_summary` AOT graph.
pub fn local_summary(
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    ctx: &SupportContext,
) -> LocalSummary {
    local_summary_ctx(&LinalgCtx::serial(), hyp, xm, ym, ctx)
}

/// [`local_summary`] with explicit linalg execution context: the Gram
/// blocks, Cholesky factorizations and triangular solves run blocked
/// and (when the ctx carries a pool *and* the caller is not already a
/// pool worker) thread-parallel.
pub fn local_summary_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    ctx: &SupportContext,
) -> LocalSummary {
    try_local_summary_ctx(lctx, hyp, xm, ym, ctx)
        .unwrap_or_else(|e| panic!("Σ_mm|S not SPD: {e}"))
}

/// Fallible [`local_summary_ctx`] — lets the facade surface a non-SPD
/// conditional covariance Σ_mm|S as a typed error.
pub fn try_local_summary_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    ctx: &SupportContext,
) -> Result<LocalSummary, crate::linalg::cholesky::NotSpd> {
    let k_ms = hyp.cov_cross_ctx(lctx, xm, &ctx.xs); // (B, S)
    // Q_mm = K_ms · Kss⁻¹ · K_sm  via W = L⁻¹ K_sm
    let w = solve_lower_mat_ctx(lctx, &ctx.l_ss, &k_ms.transpose()); // (S, B)
    let q_mm = gemm_tn(lctx, &w, &w); // (B, B)
    let mut sigma_m = hyp.cov_same_ctx(lctx, xm, true);
    sigma_m.sub_assign(&q_mm);
    let l_m = cholesky_blocked(lctx, &sigma_m)?;
    let v = cho_solve_vec(&l_m, ym);
    let y_dot = matvec(&k_ms.transpose(), &v);
    let z = cho_solve_mat_ctx(lctx, &l_m, &k_ms); // (B, S)
    let s_dot = gemm_tn(lctx, &k_ms, &z); // (S, S)
    Ok(LocalSummary { y_dot, s_dot, l_m })
}

/// Definition 3: assimilate local summaries into the global summary.
pub fn global_summary(ctx: &SupportContext, locals: &[&LocalSummary]) -> GlobalSummary {
    let s = ctx.size();
    let mut y = vec![0.0; s];
    let mut sg = ctx.sigma_ss.clone();
    for l in locals {
        assert_eq!(l.y_dot.len(), s);
        for i in 0..s {
            y[i] += l.y_dot[i];
        }
        sg.add_assign(&l.s_dot);
    }
    GlobalSummary { y, s: sg }
}

/// Incremental assimilation for online learning (§5.2): add one more
/// machine's local summary to an existing global summary.
pub fn assimilate(global: &mut GlobalSummary, l: &LocalSummary) {
    for i in 0..global.y.len() {
        global.y[i] += l.y_dot[i];
    }
    global.s.add_assign(&l.s_dot);
}

/// Cholesky of the global summary matrix with the absolute jitter used by
/// the AOT graphs (`JITTER_SCALE`, unscaled — mirrors `model.py`).
pub fn chol_global(global: &GlobalSummary) -> Mat {
    chol_global_ctx(&LinalgCtx::serial(), global)
}

/// [`chol_global`] with explicit linalg execution context.
pub fn chol_global_ctx(lctx: &LinalgCtx, global: &GlobalSummary) -> Mat {
    try_chol_global_ctx(lctx, global)
        .unwrap_or_else(|e| panic!("Σ̈_SS not SPD: {e}"))
}

/// Fallible [`chol_global_ctx`] — lets the facade surface a non-SPD
/// global summary matrix as a typed error.
pub fn try_chol_global_ctx(lctx: &LinalgCtx, global: &GlobalSummary)
    -> Result<Mat, crate::linalg::cholesky::NotSpd>
{
    let mut sg = global.s.clone();
    sg.add_diag(JITTER_SCALE);
    cholesky_blocked(lctx, &sg)
}

/// Definition 4: pPITC predictive distribution for a block U_m.
/// Mirror of the `ppitc_predict` AOT graph.
pub fn ppitc_predict(
    hyp: &SeArd,
    xu: &Mat,
    ctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
) -> Prediction {
    ppitc_predict_ctx(&LinalgCtx::serial(), hyp, xu, ctx, global, l_g)
}

/// [`ppitc_predict`] with explicit linalg execution context.
pub fn ppitc_predict_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xu: &Mat,
    ctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
) -> Prediction {
    let k_us = hyp.cov_cross_ctx(lctx, xu, &ctx.xs); // (U, S)
    let mean = matvec(&k_us, &cho_solve_vec(l_g, &global.y));
    let w1 = solve_lower_mat_ctx(lctx, &ctx.l_ss, &k_us.transpose()); // (S, U)
    let w2 = solve_lower_mat_ctx(lctx, l_g, &k_us.transpose());
    let prior = hyp.prior_var();
    let var = (0..xu.rows)
        .map(|i| {
            let t1: f64 = (0..ctx.size()).map(|s| w1[(s, i)] * w1[(s, i)]).sum();
            let t2: f64 = (0..ctx.size()).map(|s| w2[(s, i)] * w2[(s, i)]).sum();
            prior - t1 + t2
        })
        .collect();
    Prediction { mean, var }
}

/// Definition 5: pPIC predictive distribution for machine m's block U_m,
/// using both the global summary and the machine's own local data.
/// Mirror of the `ppic_predict` AOT graph (with the DESIGN.md erratum
/// correction `+ Φ Σ̈⁻¹ Φᵀ` in the variance).
#[allow(clippy::too_many_arguments)]
pub fn ppic_predict(
    hyp: &SeArd,
    xu: &Mat,
    xm: &Mat,
    ym: &[f64],
    local: &LocalSummary,
    ctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
) -> Prediction {
    ppic_predict_ctx(&LinalgCtx::serial(), hyp, xu, xm, ym, local, ctx,
                     global, l_g)
}

/// [`ppic_predict`] with explicit linalg execution context.
#[allow(clippy::too_many_arguments)]
pub fn ppic_predict_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xu: &Mat,
    xm: &Mat,
    ym: &[f64],
    local: &LocalSummary,
    ctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
) -> Prediction {
    let s = ctx.size();
    let u = xu.rows;
    let k_us = hyp.cov_cross_ctx(lctx, xu, &ctx.xs); // (U, S)
    let k_um = hyp.cov_cross_ctx(lctx, xu, xm); // (U, B)
    let k_ms = hyp.cov_cross_ctx(lctx, xm, &ctx.xs); // (B, S)

    // local-data terms (Definition 2 with B = U_m)
    let v = cho_solve_vec(&local.l_m, ym); // (B,)
    let y_dot_u = matvec(&k_um, &v); // ẏ_{U_m}^m
    let z = cho_solve_mat_ctx(lctx, &local.l_m, &k_ms); // (B, S)
    let s_dot_us = gemm(lctx, &k_um, &z); // Σ̇_US^m (U, S)
    let t = cho_solve_mat_ctx(lctx, &local.l_m, &k_um.transpose()); // (B, U)
    let s_dot_uu_diag: Vec<f64> = (0..u)
        .map(|i| (0..xm.rows).map(|b| k_um[(i, b)] * t[(b, i)]).sum())
        .collect();

    // Φ_{U_m S}^m — eq. (14)
    let kss_inv_sdot = cho_solve_mat_ctx(lctx, &ctx.l_ss, &local.s_dot); // (S, S)
    let mut phi_us = gemm(lctx, &k_us, &kss_inv_sdot); // (U, S)
    phi_us.add_assign(&k_us);
    phi_us.sub_assign(&s_dot_us);

    // mean — eq. (12)
    let gy = cho_solve_vec(l_g, &global.y);
    let ky = cho_solve_vec(&ctx.l_ss, &local.y_dot);
    let mut mean = matvec(&phi_us, &gy);
    let corr = matvec(&k_us, &ky);
    for i in 0..u {
        mean[i] += y_dot_u[i] - corr[i];
    }

    // variance — eq. (13) corrected (see DESIGN.md "Paper erratum")
    let p = cho_solve_mat_ctx(lctx, &ctx.l_ss, &k_us.transpose()); // Kss⁻¹K_su (S,U)
    let sdot_su_solved =
        cho_solve_mat_ctx(lctx, &ctx.l_ss, &s_dot_us.transpose()); // (S,U)
    let w_g = solve_lower_mat_ctx(lctx, l_g, &phi_us.transpose()); // (S, U)
    let prior = hyp.prior_var();
    let var = (0..u)
        .map(|i| {
            let diag1: f64 = (0..s).map(|r| phi_us[(i, r)] * p[(r, i)]).sum();
            let diag2: f64 =
                (0..s).map(|r| k_us[(i, r)] * sdot_su_solved[(r, i)]).sum();
            let diag3: f64 = (0..s).map(|r| w_g[(r, i)] * w_g[(r, i)]).sum();
            prior - (diag1 - diag2) - s_dot_uu_diag[i] + diag3
        })
        .collect();
    Prediction { mean, var }
}

// ------------------------------------------------------------------ ICF

/// Machine m's ICF local summary (Definition 6).
#[derive(Debug, Clone)]
pub struct IcfLocalSummary {
    /// `ẏ_m = F_m (y_m - μ_m)` — eq. (19)
    pub y_dot: Vec<f64>,
    /// `Σ̇_m = F_m Σ_{D_m U}` — eq. (20), (R × U)
    pub s_dot: Mat,
    /// `Φ_m = F_m F_mᵀ` — eq. (21), (R × R)
    pub phi: Mat,
}

impl IcfLocalSummary {
    pub fn message_bytes(&self) -> usize {
        (self.y_dot.len() + self.s_dot.data.len() + self.phi.data.len())
            * std::mem::size_of::<f64>()
    }
}

/// The ICF global summary (Definition 7): `(ÿ, Σ̈)`.
#[derive(Debug, Clone)]
pub struct IcfGlobalSummary {
    pub y: Vec<f64>,
    /// (R × U)
    pub s: Mat,
}

/// Definition 6 — mirror of the `icf_local` AOT graph. `f_m` is the
/// machine's (R × B) slab of the ICF factor of the *noise-free* K_DD.
pub fn icf_local(
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    xu: &Mat,
    f_m: &Mat,
) -> IcfLocalSummary {
    icf_local_ctx(&LinalgCtx::serial(), hyp, xm, ym, xu, f_m)
}

/// [`icf_local`] with explicit linalg execution context.
pub fn icf_local_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xm: &Mat,
    ym: &[f64],
    xu: &Mat,
    f_m: &Mat,
) -> IcfLocalSummary {
    let y_dot = matvec(f_m, ym);
    let k_mu = hyp.cov_cross_ctx(lctx, xm, xu); // (B, U)
    let s_dot = gemm(lctx, f_m, &k_mu); // (R, U)
    let phi = crate::linalg::gemm_nt(lctx, f_m, f_m); // (R, R)
    IcfLocalSummary { y_dot, s_dot, phi }
}

/// Definition 7 — mirror of the `icf_global` AOT graph.
pub fn icf_global(hyp: &SeArd, locals: &[&IcfLocalSummary]) -> IcfGlobalSummary {
    assert!(!locals.is_empty());
    let r = locals[0].phi.rows;
    let u = locals[0].s_dot.cols;
    let mut sum_y = vec![0.0; r];
    let mut sum_s = Mat::zeros(r, u);
    let mut phi = Mat::identity(r);
    let inv_sn2 = 1.0 / hyp.sn2();
    for l in locals {
        for i in 0..r {
            sum_y[i] += l.y_dot[i];
        }
        sum_s.add_assign(&l.s_dot);
        for (p, &q) in phi.data.iter_mut().zip(l.phi.data.iter()) {
            *p += inv_sn2 * q;
        }
    }
    let lctx = LinalgCtx::serial();
    let l_phi = cholesky_blocked(&lctx, &phi).expect("Φ not SPD");
    let y = cho_solve_vec(&l_phi, &sum_y);
    let s = cho_solve_mat_ctx(&lctx, &l_phi, &sum_s);
    IcfGlobalSummary { y, s }
}

/// Definition 8 — machine m's predictive *component* (additive), mirror
/// of the `icf_predict` AOT graph. The master sums components and
/// finishes with [`icf_finalize`].
pub fn icf_predict_component(
    hyp: &SeArd,
    xu: &Mat,
    xm: &Mat,
    ym: &[f64],
    s_dot_m: &Mat,
    global: &IcfGlobalSummary,
) -> Prediction {
    icf_predict_component_ctx(&LinalgCtx::serial(), hyp, xu, xm, ym,
                              s_dot_m, global)
}

/// [`icf_predict_component`] with explicit linalg execution context.
pub fn icf_predict_component_ctx(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xu: &Mat,
    xm: &Mat,
    ym: &[f64],
    s_dot_m: &Mat,
    global: &IcfGlobalSummary,
) -> Prediction {
    let inv_sn2 = 1.0 / hyp.sn2();
    let k_um = hyp.cov_cross_ctx(lctx, xu, xm); // (U, B)
    let mut mean = matvec(&k_um, ym);
    for v in mean.iter_mut() {
        *v *= inv_sn2;
    }
    let st_y = matvec(&s_dot_m.transpose(), &global.y);
    let u = xu.rows;
    let r = s_dot_m.rows;
    for i in 0..u {
        mean[i] -= inv_sn2 * inv_sn2 * st_y[i];
    }
    let var = (0..u)
        .map(|i| {
            let kk: f64 = (0..xm.rows).map(|b| k_um[(i, b)] * k_um[(i, b)]).sum();
            let ss: f64 =
                (0..r).map(|t| s_dot_m[(t, i)] * global.s[(t, i)]).sum();
            inv_sn2 * kk - inv_sn2 * inv_sn2 * ss
        })
        .collect();
    Prediction { mean, var }
}

/// Definition 9: master combines predictive components into the final
/// distribution: `μ̃ = Σ μ̃^m`, `Σ̃_diag = (sf2+sn2) − Σ σ̃²^m`.
pub fn icf_finalize(hyp: &SeArd, u: usize, components: &[&Prediction]) -> Prediction {
    let mut mean = vec![0.0; u];
    let mut var_sub = vec![0.0; u];
    for c in components {
        assert_eq!(c.len(), u);
        for i in 0..u {
            mean[i] += c.mean[i];
            var_sub[i] += c.var[i];
        }
    }
    let prior = hyp.prior_var();
    let var = var_sub.iter().map(|&v| prior - v).collect();
    Prediction { mean, var }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{cho_solve_mat, cholesky, matmul, matmul_tn};
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.5, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// ẏ and Σ̇ satisfy their defining equations (3)-(4) directly.
    #[test]
    fn local_summary_matches_definitions() {
        prop_check("local-summary-def", 10, |g| {
            let d = g.usize_in(1, 4);
            let b = g.usize_in(2, 8);
            let s = g.usize_in(1, 6);
            let hyp = rand_hyp(g, d);
            let xm = Mat::from_vec(b, d, g.uniform_vec(b * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let ym = g.normal_vec(b);
            let ctx = SupportContext::new(&hyp, &xs);
            let loc = local_summary(&hyp, &xm, &ym, &ctx);

            // direct: Σ_mm|S = Σ_mm − K_ms Kss⁻¹ K_sm (with jitters)
            let k_ms = hyp.cov_cross(&xm, &xs);
            let q = matmul(
                &k_ms,
                &cho_solve_mat(&ctx.l_ss, &k_ms.transpose()),
            );
            let mut sig = hyp.cov_same(&xm, true);
            sig.sub_assign(&q);
            let recomposed = crate::linalg::matmul_nt(&loc.l_m, &loc.l_m);
            assert!(recomposed.max_abs_diff(&sig) < 1e-9);

            let l_sig = cholesky(&sig).unwrap();
            let want_y = matvec(&k_ms.transpose(), &cho_solve_vec(&l_sig, &ym));
            assert_all_close(&loc.y_dot, &want_y, 1e-8, 1e-8);
            let want_s =
                matmul_tn(&k_ms, &cho_solve_mat(&l_sig, &k_ms));
            assert!(loc.s_dot.max_abs_diff(&want_s) < 1e-8);
        });
    }

    /// Global summary sums per eqs. (5)-(6), and assimilate() agrees.
    #[test]
    fn global_summary_accumulates() {
        prop_check("global-summary", 8, |g| {
            let d = 2;
            let s = 4;
            let hyp = rand_hyp(g, d);
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let ctx = SupportContext::new(&hyp, &xs);
            let mut locals = Vec::new();
            for _ in 0..3 {
                let b = 5;
                let xm = Mat::from_vec(b, d, g.uniform_vec(b * d, -2.0, 2.0));
                let ym = g.normal_vec(b);
                locals.push(local_summary(&hyp, &xm, &ym, &ctx));
            }
            let refs: Vec<&LocalSummary> = locals.iter().collect();
            let glob = global_summary(&ctx, &refs);

            // incremental assimilation gives the same result
            let mut inc = global_summary(&ctx, &refs[..1]);
            assimilate(&mut inc, refs[1]);
            assimilate(&mut inc, refs[2]);
            assert_all_close(&glob.y, &inc.y, 1e-12, 1e-12);
            assert!(glob.s.max_abs_diff(&inc.s) < 1e-12);

            // Σ̈−Σ_SS = ΣΣ̇ᵐ
            let mut sum_dot = ctx.sigma_ss.clone();
            for l in &locals {
                sum_dot.add_assign(&l.s_dot);
            }
            assert!(glob.s.max_abs_diff(&sum_dot) < 1e-12);
        });
    }

    /// pPITC variance falls between 0 and the prior variance, and the
    /// global-summary term only *adds* variance vs. the PITC-free limit.
    #[test]
    fn ppitc_prediction_sanity() {
        prop_check("ppitc-sanity", 8, |g| {
            let d = 2;
            let hyp = rand_hyp(g, d);
            let xs = Mat::from_vec(4, d, g.uniform_vec(8, -2.0, 2.0));
            let xm = Mat::from_vec(6, d, g.uniform_vec(12, -2.0, 2.0));
            let ym = g.normal_vec(6);
            let xu = Mat::from_vec(5, d, g.uniform_vec(10, -2.0, 2.0));
            let ctx = SupportContext::new(&hyp, &xs);
            let loc = local_summary(&hyp, &xm, &ym, &ctx);
            let glob = global_summary(&ctx, &[&loc]);
            let l_g = chol_global(&glob);
            let pred = ppitc_predict(&hyp, &xu, &ctx, &glob, &l_g);
            assert_eq!(pred.len(), 5);
            for &v in &pred.var {
                assert!(v > 0.0 && v <= hyp.prior_var() + 1e-9, "var={v}");
            }
        });
    }

    /// ICF pieces satisfy their definitions with a random factor F.
    #[test]
    fn icf_summary_definitions() {
        prop_check("icf-defs", 8, |g| {
            let d = 2;
            let (b, u, r) = (5, 4, 3);
            let hyp = rand_hyp(g, d);
            let xm = Mat::from_vec(b, d, g.uniform_vec(b * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let ym = g.normal_vec(b);
            let f_m = Mat::from_vec(r, b, g.normal_vec(r * b));
            let loc = icf_local(&hyp, &xm, &ym, &xu, &f_m);
            assert_all_close(&loc.y_dot, &matvec(&f_m, &ym), 1e-12, 1e-12);
            let want_phi = crate::linalg::matmul_nt(&f_m, &f_m);
            assert!(loc.phi.max_abs_diff(&want_phi) < 1e-12);

            // global solve satisfies Φ·ÿ = Σẏ
            let glob = icf_global(&hyp, &[&loc]);
            let mut phi = Mat::identity(r);
            let inv_sn2 = 1.0 / hyp.sn2();
            for i in 0..r {
                for j in 0..r {
                    phi[(i, j)] += inv_sn2 * loc.phi[(i, j)];
                }
            }
            let back = matvec(&phi, &glob.y);
            assert_all_close(&back, &loc.y_dot, 1e-9, 1e-9);
        });
    }

    /// Finalize: prior − Σ components.
    #[test]
    fn icf_finalize_combines() {
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let c1 = Prediction { mean: vec![1.0, 2.0], var: vec![0.2, 0.3] };
        let c2 = Prediction { mean: vec![0.5, -1.0], var: vec![0.1, 0.2] };
        let out = icf_finalize(&hyp, 2, &[&c1, &c2]);
        assert_all_close(&out.mean, &[1.5, 1.0], 1e-12, 1e-12);
        let prior = hyp.prior_var();
        assert_all_close(&out.var, &[prior - 0.3, prior - 0.5], 1e-12, 1e-12);
    }
}
