//! Centralized ICF-based GP (Section 4), eqs. (28)-(29) — the sequential
//! counterpart of pICF-based GP (Theorem 3).
//!
//! Approximates Σ_DD ≈ FᵀF + sn2·I with a rank-R incomplete Cholesky of
//! the noise-free Gram matrix, then predicts through the Woodbury
//! identity — the same algebra Definitions 6–9 distribute.

use std::sync::OnceLock;

use super::predictor::{icf_operator, PredictOperator};
use super::summaries::{
    icf_finalize, icf_global, icf_local_ctx, icf_predict_component_ctx,
    IcfGlobalSummary, IcfLocalSummary,
};
use super::Prediction;
use crate::kernel::SeArd;
use crate::linalg::icf::KernelSource;
use crate::linalg::{icf_ctx, LinalgCtx, Mat};

/// Implicit noise-free Gram-matrix source for ICF (never materializes
/// the n×n matrix; the paper's point is R ≪ n).
pub struct GramSource<'a> {
    pub hyp: &'a SeArd,
    pub x: &'a Mat,
}

impl KernelSource for GramSource<'_> {
    fn n(&self) -> usize {
        self.x.rows
    }
    fn diag(&self, _i: usize) -> f64 {
        self.hyp.sf2()
    }
    fn row(&self, i: usize, out: &mut [f64]) {
        let xi = self.x.row(i);
        for j in 0..self.x.rows {
            out[j] = self.hyp.k(xi, self.x.row(j));
        }
    }
}

/// Fitted centralized ICF-based GP.
#[derive(Debug, Clone)]
pub struct IcfGp {
    hyp: SeArd,
    /// per machine: (X_m, centered y_m, F_m slab)
    blocks: Vec<(Mat, Vec<f64>, Mat)>,
    /// achieved rank (≤ requested; ICF may converge early)
    pub rank: usize,
    pub y_mean: f64,
    /// Serve-path operator (low-rank `V = sn⁻²·L_Φ̃⁻¹F` form), built
    /// lazily on first [`IcfGp::predictor`] call.
    op: OnceLock<PredictOperator>,
}

impl IcfGp {
    /// Fit: rank-R pivoted ICF of K_DD, then stash per-block slabs F_m
    /// exactly as Step 2 of the paper distributes them.
    pub fn fit(
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        rank: usize,
        d_blocks: &[Vec<usize>],
    ) -> IcfGp {
        IcfGp::fit_ctx(&LinalgCtx::serial(), hyp, xd, y, rank, d_blocks)
    }

    /// [`IcfGp::fit`] with explicit linalg execution context: the
    /// pivoted ICF's per-step updates fan out over column bands
    /// ([`crate::linalg::icf_ctx`]), bitwise-identical to serial.
    pub fn fit_ctx(
        lctx: &LinalgCtx,
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        rank: usize,
        d_blocks: &[Vec<usize>],
    ) -> IcfGp {
        assert_eq!(xd.rows, y.len());
        let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
        let src = GramSource { hyp, x: xd };
        let factor = icf_ctx(lctx, &src, rank, 0.0);
        let r = factor.f.rows;
        let blocks = d_blocks
            .iter()
            .map(|blk| {
                let xm = xd.select_rows(blk);
                let ym: Vec<f64> = blk.iter().map(|&i| y[i] - y_mean).collect();
                // F_m = F[:, blk] (column slab in the block's row order)
                let mut f_m = Mat::zeros(r, blk.len());
                for row in 0..r {
                    for (c, &i) in blk.iter().enumerate() {
                        f_m[(row, c)] = factor.f[(row, i)];
                    }
                }
                (xm, ym, f_m)
            })
            .collect();
        IcfGp { hyp: hyp.clone(), blocks, rank: r, y_mean,
                op: OnceLock::new() }
    }

    /// The staged predictive operator (built on first call, cached):
    /// Definitions 7–9 collapsed to one GEMV + a rank-R correction.
    /// Equal to [`IcfGp::predict`] ≤1e-12 (tested).
    pub fn predictor(&self, lctx: &LinalgCtx) -> &PredictOperator {
        self.op.get_or_init(|| {
            let refs: Vec<(&Mat, &[f64], &Mat)> = self
                .blocks
                .iter()
                .map(|(xm, ym, f_m)| (xm, ym.as_slice(), f_m))
                .collect();
            icf_operator(lctx, &self.hyp, &refs, self.y_mean)
        })
    }

    /// Serve-path prediction through [`IcfGp::predictor`].
    pub fn predict_fast_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        self.predictor(lctx).predict_ctx(lctx, xu)
    }

    /// Steps 3–6 executed on one machine: local summaries → global
    /// summary → predictive components → finalize (serial ctx).
    pub fn predict(&self, xu: &Mat) -> Prediction {
        self.predict_ctx(&LinalgCtx::serial(), xu)
    }

    /// [`IcfGp::predict`] with explicit linalg execution context (the
    /// R×R global solve stays serial — it is negligible).
    pub fn predict_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        let locals: Vec<IcfLocalSummary> = self
            .blocks
            .iter()
            .map(|(xm, ym, f_m)| icf_local_ctx(lctx, &self.hyp, xm, ym, xu, f_m))
            .collect();
        let refs: Vec<&IcfLocalSummary> = locals.iter().collect();
        let global: IcfGlobalSummary = icf_global(&self.hyp, &refs);
        let comps: Vec<Prediction> = self
            .blocks
            .iter()
            .zip(locals.iter())
            .map(|((xm, ym, _), loc)| {
                icf_predict_component_ctx(lctx, &self.hyp, xu, xm, ym,
                                          &loc.s_dot, &global)
            })
            .collect();
        let crefs: Vec<&Prediction> = comps.iter().collect();
        let mut p = icf_finalize(&self.hyp, xu.rows, &crefs);
        p.shift_mean(self.y_mean);
        p
    }
}

/// Literal transcription of eqs. (28)-(29) with an explicit factor F —
/// O(|D|³) dense oracle used only by tests (Theorem 3 ground truth).
pub fn icf_direct_oracle(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xu: &Mat,
    f: &Mat,
) -> Prediction {
    use crate::linalg::{cho_solve_mat, cho_solve_vec, cholesky, matmul_tn, matvec};
    let n = xd.rows;
    let y_mean = y.iter().sum::<f64>() / n as f64;
    let centered: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    // A = FᵀF + sn2 I  (n×n dense — test-only)
    let mut a = matmul_tn(f, f);
    a.add_diag(hyp.sn2());
    let l = cholesky(&a).expect("FᵀF + sn2 I not SPD");
    let k_ud = hyp.cov_cross(xu, xd);
    let mut mean = matvec(&k_ud, &cho_solve_vec(&l, &centered));
    for v in mean.iter_mut() {
        *v += y_mean;
    }
    let w = cho_solve_mat(&l, &k_ud.transpose()); // (n, U)
    let prior = hyp.prior_var();
    let var = (0..xu.rows)
        .map(|i| {
            let t: f64 = (0..n).map(|r| k_ud[(i, r)] * w[(r, i)]).sum();
            prior - t
        })
        .collect();
    Prediction { mean, var }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::linalg::icf;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// Theorem 3: the distributed-form implementation equals the literal
    /// eqs. (28)-(29) with the same factor F.
    #[test]
    fn theorem3_block_equals_direct() {
        prop_check("thm3-icf", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = g.usize_in(1, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());

            let model = IcfGp::fit(&hyp, &xd, &y, rank, &d_blocks);
            let got = model.predict(&xu);

            // reconstruct the full F in training-row order for the oracle
            let src = GramSource { hyp: &hyp, x: &xd };
            let factor = icf(&src, rank, 0.0);
            let want = icf_direct_oracle(&hyp, &xd, &y, &xu, &factor.f);
            assert_all_close(&got.mean, &want.mean, 1e-6, 1e-6);
            assert_all_close(&got.var, &want.var, 1e-6, 1e-6);
        });
    }

    /// The staged operator reproduces the seed component pipeline to
    /// ≤1e-12 (including achieved-rank < requested cases).
    #[test]
    fn fast_path_matches_component_pipeline() {
        prop_check("icf-fast-vs-solve", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = g.usize_in(1, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());
            let model = IcfGp::fit(&hyp, &xd, &y, rank, &d_blocks);
            let want = model.predict(&xu);
            let got = model.predict_fast_ctx(&LinalgCtx::serial(), &xu);
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        });
    }

    /// Full rank R = n recovers FGP exactly (ICF becomes exact Cholesky).
    #[test]
    fn full_rank_recovers_fgp() {
        let n = 12;
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.05);
        let xd = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.37).collect());
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let blocks = random_partition(n, 3, &mut crate::util::Pcg64::seed(1));
        let model = IcfGp::fit(&hyp, &xd, &y, n, &blocks);
        let fgp = crate::gp::FullGp::fit(&hyp, &xd, &y);
        let xu = Mat::from_vec(5, 1, vec![0.1, 0.9, 1.8, 2.9, 4.0]);
        let got = model.predict(&xu);
        let want = fgp.predict(&xu);
        // jitter policies differ slightly (ICF has none on Σ_DD) — modest tol
        assert_all_close(&got.mean, &want.mean, 1e-5, 1e-5);
        assert_all_close(&got.var, &want.var, 1e-5, 1e-5);
    }

    /// Small rank can produce non-PSD variance (the paper's Remark 2
    /// after Theorem 3) but larger ranks must fix it.
    #[test]
    fn rank_controls_variance_positivity() {
        let mut rng = crate::util::Pcg64::seed(5);
        let n = 30;
        let hyp = SeArd::isotropic(2, 0.4, 1.0, 1e-3);
        let xd = Mat::from_vec(n, 2, rng.normals(n * 2));
        let y = rng.normals(n);
        let blocks = random_partition(n, 5, &mut rng);
        let xu = Mat::from_vec(8, 2, rng.normals(16));
        let lo = IcfGp::fit(&hyp, &xd, &y, 2, &blocks).predict(&xu);
        let hi = IcfGp::fit(&hyp, &xd, &y, n, &blocks).predict(&xu);
        let neg_lo = crate::metrics::frac_nonpositive_var(&lo.var);
        let neg_hi = crate::metrics::frac_nonpositive_var(&hi.var);
        assert!(neg_hi <= neg_lo);
        assert_eq!(neg_hi, 0.0);
    }

    /// Prediction error decreases with rank on smooth data.
    #[test]
    fn error_decreases_with_rank() {
        let n = 40;
        let hyp = SeArd::isotropic(1, 0.8, 1.0, 1e-4);
        let xd = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.1).collect());
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1 * 2.0).sin()).collect();
        let blocks = random_partition(n, 4, &mut crate::util::Pcg64::seed(3));
        let xu = Mat::from_vec(6, 1, vec![0.15, 0.85, 1.55, 2.25, 2.95, 3.65]);
        let y_true: Vec<f64> = vec![0.15f64, 0.85, 1.55, 2.25, 2.95, 3.65]
            .iter()
            .map(|&x| (2.0 * x).sin())
            .collect();
        let mut prev = f64::INFINITY;
        for rank in [2, 8, 24] {
            let p = IcfGp::fit(&hyp, &xd, &y, rank, &blocks).predict(&xu);
            let e = crate::metrics::rmse(&y_true, &p.mean);
            assert!(e <= prev + 1e-6, "rank {rank}: {e} > {prev}");
            prev = e;
        }
        assert!(prev < 0.05);
    }
}
