//! Centralized PIC approximation (Snelson 2007), eqs. (15)-(18) — the
//! sequential counterpart of pPIC (Theorem 2).
//!
//! PIC = PITC + exact cross-covariance on each machine's own
//! (D_m, U_m) pair, so predictions are tied to the test partition: block
//! U_m is predicted with machine m's local data. Numerically identical
//! to pPIC by Theorem 2 (tested against the literal eqs. (15)-(16)).

use std::sync::OnceLock;

use super::predictor::{ppic_operators, PredictOperator};
use super::summaries::{
    global_summary, ppic_predict_ctx, try_chol_global_ctx,
    try_local_summary_ctx, GlobalSummary, LocalSummary, SupportContext,
};
use super::Prediction;
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};

/// Fitted centralized PIC model (keeps per-block local data).
#[derive(Debug, Clone)]
pub struct PicGp {
    hyp: SeArd,
    ctx: SupportContext,
    global: GlobalSummary,
    l_g: Mat,
    /// per machine: (X_m, centered y_m, local summary)
    blocks: Vec<(Mat, Vec<f64>, LocalSummary)>,
    pub y_mean: f64,
    /// Per-machine serve-path operators (Definition 5 over stacked
    /// `[k(u,S); k(u,X_m)]` features), built lazily on first
    /// [`PicGp::predictors`] call.
    ops: OnceLock<Vec<PredictOperator>>,
}

impl PicGp {
    pub fn fit(
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
    ) -> PicGp {
        PicGp::fit_ctx(&LinalgCtx::serial(), hyp, xd, y, xs, d_blocks)
    }

    /// [`PicGp::fit`] with explicit linalg execution context (the
    /// sweep harness passes the cluster executor's pooled ctx).
    pub fn fit_ctx(
        lctx: &LinalgCtx,
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
    ) -> PicGp {
        PicGp::try_fit_ctx(lctx, hyp, xd, y, xs, d_blocks)
            .unwrap_or_else(|e| panic!("PIC fit: covariance not SPD: {e}"))
    }

    /// Fallible [`PicGp::fit_ctx`] — the facade ([`crate::api`])
    /// reports non-SPD covariances as typed errors instead of panicking.
    pub fn try_fit_ctx(
        lctx: &LinalgCtx,
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
    ) -> Result<PicGp, crate::linalg::cholesky::NotSpd> {
        assert_eq!(xd.rows, y.len());
        let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
        let ctx = SupportContext::try_new_ctx(lctx, hyp, xs)?;
        let mut blocks = Vec::with_capacity(d_blocks.len());
        for blk in d_blocks {
            let xm = xd.select_rows(blk);
            let ym: Vec<f64> = blk.iter().map(|&i| y[i] - y_mean).collect();
            let loc = try_local_summary_ctx(lctx, hyp, &xm, &ym, &ctx)?;
            blocks.push((xm, ym, loc));
        }
        let refs: Vec<_> = blocks.iter().map(|(_, _, l)| l).collect();
        let global = global_summary(&ctx, &refs);
        let l_g = try_chol_global_ctx(lctx, &global)?;
        Ok(PicGp {
            hyp: hyp.clone(),
            ctx,
            global,
            l_g,
            blocks,
            y_mean,
            ops: OnceLock::new(),
        })
    }

    pub fn n_machines(&self) -> usize {
        self.blocks.len()
    }

    /// The staged per-machine predictive operators (built on first
    /// call, cached). `predictors()[m]` equals
    /// [`PicGp::predict_block`] on machine m ≤1e-12 (tested).
    pub fn predictors(&self, lctx: &LinalgCtx) -> &[PredictOperator] {
        self.ops.get_or_init(|| {
            ppic_operators(lctx, &self.hyp, &self.ctx, &self.global,
                           &self.l_g, &self.blocks, self.y_mean)
        })
    }

    /// Serve-path block prediction through [`PicGp::predictors`].
    pub fn predict_fast_block_ctx(&self, lctx: &LinalgCtx, xu_m: &Mat,
                                  m: usize) -> Prediction {
        self.predictors(lctx)[m].predict_ctx(lctx, xu_m)
    }

    /// Serve-path prediction of a partitioned test set through the
    /// staged operators (same contract as [`PicGp::predict`]).
    pub fn predict_fast_ctx(&self, lctx: &LinalgCtx, xu: &Mat,
                            u_blocks: &[Vec<usize>]) -> Prediction {
        assert_eq!(u_blocks.len(), self.blocks.len());
        let preds: Vec<Prediction> = u_blocks
            .iter()
            .enumerate()
            .map(|(m, blk)| {
                self.predict_fast_block_ctx(lctx, &xu.select_rows(blk), m)
            })
            .collect();
        Prediction::scatter(&preds, u_blocks, xu.rows)
    }

    /// Predict test block `u_block` rows of `xu` with machine `m`'s view
    /// (Definition 5). `u_blocks[m]` must index into `xu`.
    pub fn predict_block(&self, xu_m: &Mat, m: usize) -> Prediction {
        self.predict_block_ctx(&LinalgCtx::serial(), xu_m, m)
    }

    /// [`PicGp::predict_block`] with explicit linalg execution context.
    pub fn predict_block_ctx(&self, lctx: &LinalgCtx, xu_m: &Mat, m: usize)
        -> Prediction
    {
        let (xm, ym, loc) = &self.blocks[m];
        let mut p = ppic_predict_ctx(
            lctx, &self.hyp, xu_m, xm, ym, loc, &self.ctx, &self.global,
            &self.l_g,
        );
        p.shift_mean(self.y_mean);
        p
    }

    /// Predict the full test set given its Definition-1 partition.
    pub fn predict(&self, xu: &Mat, u_blocks: &[Vec<usize>]) -> Prediction {
        self.predict_ctx(&LinalgCtx::serial(), xu, u_blocks)
    }

    /// [`PicGp::predict`] with explicit linalg execution context.
    pub fn predict_ctx(&self, lctx: &LinalgCtx, xu: &Mat,
                       u_blocks: &[Vec<usize>]) -> Prediction {
        assert_eq!(u_blocks.len(), self.blocks.len());
        let preds: Vec<Prediction> = u_blocks
            .iter()
            .enumerate()
            .map(|(m, blk)| {
                self.predict_block_ctx(lctx, &xu.select_rows(blk), m)
            })
            .collect();
        Prediction::scatter(&preds, u_blocks, xu.rows)
    }
}

/// Literal transcription of eqs. (15)-(18) — O(|D|³) dense oracle used
/// only by tests (Theorem 2 ground truth).
pub fn pic_direct_oracle(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    u_blocks: &[Vec<usize>],
) -> Prediction {
    use crate::linalg::{cho_solve_mat, cho_solve_vec, cholesky, matmul, matvec};
    let n = xd.rows;
    let y_mean = y.iter().sum::<f64>() / n as f64;
    let centered: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

    let ctx = SupportContext::new(hyp, xs);
    let k_ds = hyp.cov_cross(xd, xs);
    let k_us = hyp.cov_cross(xu, xs);
    let kss_inv_ksd = cho_solve_mat(&ctx.l_ss, &k_ds.transpose());
    let gamma_dd = matmul(&k_ds, &kss_inv_ksd);
    let gamma_ud = matmul(&k_us, &kss_inv_ksd);

    let sigma_dd = hyp.cov_same(xd, false);
    let mut a = gamma_dd.clone();
    for blk in d_blocks {
        for &i in blk {
            for &j in blk {
                a[(i, j)] = sigma_dd[(i, j)];
            }
            a[(i, i)] += hyp.jitter();
        }
    }
    let l_a = cholesky(&a).expect("Γ_DD + Λ not SPD");

    // Γ̃_UD: exact cross-covariance on own (U_m, D_m) blocks — eq. (18)
    let mut gt = gamma_ud.clone();
    let k_ud = hyp.cov_cross(xu, xd);
    for (m, ub) in u_blocks.iter().enumerate() {
        for &ui in ub {
            for &di in &d_blocks[m] {
                gt[(ui, di)] = k_ud[(ui, di)];
            }
        }
    }

    let mut mean = matvec(&gt, &cho_solve_vec(&l_a, &centered));
    for v in mean.iter_mut() {
        *v += y_mean;
    }
    let w = cho_solve_mat(&l_a, &gt.transpose()); // (n, U)
    let prior = hyp.prior_var();
    let var = (0..xu.rows)
        .map(|i| {
            let t: f64 = (0..n).map(|r| gt[(i, r)] * w[(r, i)]).sum();
            prior - t
        })
        .collect();
    Prediction { mean, var }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// Theorem 2: the distributed-form implementation (with the DESIGN.md
    /// variance erratum fix) equals the literal eqs. (15)-(16).
    #[test]
    fn theorem2_block_equals_direct() {
        prop_check("thm2-pic", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = m * g.usize_in(1, 3);
            let s = g.usize_in(2, 5);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());
            let u_blocks = random_partition(u, m, g.rng());

            let model = PicGp::fit(&hyp, &xd, &y, &xs, &d_blocks);
            let got = model.predict(&xu, &u_blocks);
            let want =
                pic_direct_oracle(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks);
            assert_all_close(&got.mean, &want.mean, 1e-6, 1e-6);
            assert_all_close(&got.var, &want.var, 1e-6, 1e-6);
        });
    }

    /// The staged per-machine operators reproduce the seed
    /// solve-based Definition-5 predict to ≤1e-12.
    #[test]
    fn fast_path_matches_solve_path() {
        prop_check("pic-fast-vs-solve", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = m * g.usize_in(1, 3);
            let s = g.usize_in(2, 5);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());
            let u_blocks = random_partition(u, m, g.rng());
            let model = PicGp::fit(&hyp, &xd, &y, &xs, &d_blocks);
            let want = model.predict(&xu, &u_blocks);
            let got = model.predict_fast_ctx(
                &crate::linalg::LinalgCtx::serial(), &xu, &u_blocks);
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        });
    }

    /// PIC with S = D reduces to FGP as sn2 → 0 (see note in pitc.rs on
    /// the paper-literal noisy Σ_SS convention).
    #[test]
    fn s_equals_d_recovers_fgp() {
        let n = 10;
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 1e-6);
        let xd = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.4).collect());
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.4).cos()).collect();
        let d_blocks = vec![(0..5).collect::<Vec<_>>(), (5..10).collect()];
        let model = PicGp::fit(&hyp, &xd, &y, &xd, &d_blocks);
        let xu = Mat::from_vec(4, 1, vec![0.2, 1.1, 2.3, 3.3]);
        let u_blocks = vec![vec![0, 1], vec![2, 3]];
        let got = model.predict(&xu, &u_blocks);
        let fgp = crate::gp::FullGp::fit(&hyp, &xd, &y);
        let want = fgp.predict(&xu);
        assert_all_close(&got.mean, &want.mean, 1e-4, 1e-4);
        assert_all_close(&got.var, &want.var, 1e-4, 1e-4);
    }

    /// PIC beats PITC on data where local structure matters (short
    /// length-scale relative to the support coverage).
    #[test]
    fn pic_beats_pitc_on_local_structure() {
        let mut rng = crate::util::Pcg64::seed(77);
        let n = 40;
        let hyp = SeArd::isotropic(1, 0.15, 1.0, 1e-3);
        let xvals: Vec<f64> = (0..n).map(|i| i as f64 * 0.1).collect();
        let xd = Mat::from_vec(n, 1, xvals.clone());
        let y: Vec<f64> = xvals.iter().map(|&x| (7.0 * x).sin()).collect();
        // sparse support: 4 points — PITC loses the local detail
        let xs = Mat::from_vec(4, 1, vec![0.0, 1.3, 2.6, 3.9]);
        // contiguous blocks so U_m sits inside D_m's territory
        let d_blocks: Vec<Vec<usize>> =
            (0..4).map(|m| (m * 10..(m + 1) * 10).collect()).collect();
        let model = PicGp::fit(&hyp, &xd, &y, &xs, &d_blocks);
        let pitc = crate::gp::pitc::PitcGp::fit(&hyp, &xd, &y, &xs, &d_blocks);

        // test points near block centers
        let xu_vals: Vec<f64> = (0..8).map(|i| 0.25 + 0.5 * i as f64).collect();
        let xu = Mat::from_vec(8, 1, xu_vals.clone());
        let u_blocks: Vec<Vec<usize>> =
            (0..4).map(|m| vec![2 * m, 2 * m + 1]).collect();
        let y_true: Vec<f64> = xu_vals.iter().map(|&x| (7.0 * x).sin()).collect();

        let pic_pred = model.predict(&xu, &u_blocks);
        let pitc_pred = pitc.predict(&xu);
        let pic_rmse = crate::metrics::rmse(&y_true, &pic_pred.mean);
        let pitc_rmse = crate::metrics::rmse(&y_true, &pitc_pred.mean);
        assert!(
            pic_rmse < pitc_rmse,
            "PIC {pic_rmse:.4} should beat PITC {pitc_rmse:.4}"
        );
        let _ = rng.next_u64();
    }
}
