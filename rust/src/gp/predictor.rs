//! The predictive-operator layer: fit-time precomputation of the
//! query-independent pieces of every method's predictive equations.
//!
//! The seed predict paths re-derive per batch what never changes across
//! batches — triangular solves against the support/global Cholesky
//! factors, and (through [`crate::runtime::Backend`]) even the O(|S|³)
//! factorizations themselves. A [`PredictOperator`] collapses all of it
//! into three staged objects:
//!
//! * a [`FeatureMap`] (scaled source rows + norms, so the
//!   cross-covariance per batch is one GEMM + banded exp),
//! * a weight vector `w` with `mean = G·w + ȳ` — one GEMV,
//! * a symmetric variance operator `A` with `σ²ᵢ = c₀ − gᵢᵀ·A·gᵢ`
//!   evaluated by the fused [`diag_quad_into`] kernel (or, for the
//!   ICF family, the low-rank form `σ²ᵢ = c₀ − sn⁻²·‖gᵢ‖² +
//!   ‖V·gᵢ‖²` that preserves the R ≪ |D| cost structure).
//!
//! Per method (all numpy-cross-validated against the seed paths to
//! ≤1e-14 before transcription, and property-tested ≤1e-12 in-tree):
//!
//! * **FGP** — `w = Σ_DD⁻¹(y−ȳ)` (the classic α), `A = Σ_DD⁻¹`.
//! * **PITC/pPITC** — `w = Σ̈_SS⁻¹ÿ_S`, `A = Σ_SS⁻¹ − Σ̈_SS⁻¹`
//!   (Definition 4's two solve pipelines as one operator).
//! * **PIC/pPIC/online** — per machine, over the stacked features
//!   `g = [k(u,S); k(u,X_m)]`: `w = [P·ĝ − Σ_SS⁻¹ẏ_S^m ;
//!   Σ_mm|S⁻¹y_m − Z·ĝ]` and `A = [[P·Σ_SS⁻¹, −Σ_SS⁻¹Zᵀ],
//!   [−Z·Σ_SS⁻¹, Σ_mm|S⁻¹]] − C·Σ̈_SS⁻¹·Cᵀ` with
//!   `P = I + Σ_SS⁻¹Σ̇_SS^m`, `Z = Σ_mm|S⁻¹Σ_mS`, `C = [P; −Z]`,
//!   `ĝ = Σ̈_SS⁻¹ÿ_S` (Definition 5 with the DESIGN.md variance
//!   erratum folded in).
//! * **ICF/pICF** — `w` concatenates `sn⁻²·y_m − sn⁻⁴·F_mᵀÿ` per
//!   machine and the low-rank term uses `V = sn⁻²·L_Φ̃⁻¹F`
//!   (Definitions 8–9 collapsed; `Φ̃ = I + sn⁻²·F·Fᵀ`).
//!
//! The seed solve-based paths stay untouched as the equivalence
//! oracles; every operator is pinned to them in tests.
//!
//! An opt-in mixed-precision serve form ([`PredictOperatorF32`],
//! reached via [`PredictOperator::demote`]) stores every staged array
//! in f32 while accumulating in f64, within [`F32_SERVE_REL_BUDGET`]
//! of the f64 operator (asserted below, re-measured by BENCH_serve).

use super::summaries::{GlobalSummary, LocalSummary, SupportContext};
use super::Prediction;
use crate::kernel::{FeatureMap, FeatureMapF32, FeatureScratch, SeArd};
use crate::linalg::simd::mixed::{
    axpy_wide, diag_quad_f32_into, dot_wide, MatF32,
};
use crate::linalg::{
    cho_solve_mat_ctx, cho_solve_vec, cholesky_blocked, diag_quad_into,
    gemm, gemm_into, gemm_nt, gemm_tn, matvec, matvec_t,
    solve_lower_mat_ctx, LinalgCtx, Mat,
};

/// The variance form a [`PredictOperator`] evaluates per query row.
#[derive(Debug, Clone)]
enum QuadTerm {
    /// `σ²ᵢ = c₀ − gᵢᵀ·A·gᵢ` with A symmetric p×p (fused kernel).
    Dense(Mat),
    /// `σ²ᵢ = c₀ − diag_coef·‖gᵢ‖² + ‖hᵢ‖²` with `H = G·vt`
    /// (vt: p×R, the transposed low-rank factor V stored for a direct
    /// GEMM). Keeps ICF's R ≪ |D| cost structure.
    LowRank { diag_coef: f64, vt: Mat },
}

/// Reusable buffers for [`PredictOperator::predict_into`]: the feature
/// matrix, the low-rank intermediate, and the [`FeatureScratch`].
/// Steady-state batches of stable shape allocate nothing.
#[derive(Debug, Clone)]
pub struct OpScratch {
    feat: FeatureScratch,
    g: Mat,
    h: Mat,
}

impl OpScratch {
    #[must_use]
    pub fn new() -> OpScratch {
        OpScratch {
            feat: FeatureScratch::new(),
            g: Mat::zeros(0, 0),
            h: Mat::zeros(0, 0),
        }
    }
}

impl Default for OpScratch {
    fn default() -> OpScratch {
        OpScratch::new()
    }
}

/// A staged predictive distribution: everything query-independent,
/// precomputed once. `predict` is one feature GEMM, one GEMV and one
/// fused quadratic-form pass — no factorizations, no solves.
#[derive(Debug, Clone)]
pub struct PredictOperator {
    feat: FeatureMap,
    /// mean weights (p)
    w: Vec<f64>,
    /// prior mean added to every predictive mean
    y_mean: f64,
    /// variance offset (the prior variance sf² + sn²)
    c0: f64,
    quad: QuadTerm,
}

impl PredictOperator {
    /// Feature dimension p (|S|, |S|+|B_m| or |D| depending on method).
    #[must_use]
    pub fn p(&self) -> usize {
        self.feat.p()
    }

    /// Input dimensionality d.
    #[must_use]
    pub fn dim(&self) -> usize {
        self.feat.dim()
    }

    /// Serve-path prediction into caller-owned outputs: `q` is the
    /// row-major query batch (rows × d); `mean`/`var` are resized to
    /// `rows`. Nothing else is allocated once `scratch` is warm.
    /// Pooled execution (a ctx carrying a pool) is bitwise-identical
    /// to serial, and each row's outputs are independent of the other
    /// rows in the batch — padding is transparent.
    pub fn predict_into(
        &self,
        lctx: &LinalgCtx,
        q: &[f64],
        rows: usize,
        scratch: &mut OpScratch,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
    ) {
        self.feat.fill(lctx, q, rows, &mut scratch.g, &mut scratch.feat);
        mean.resize(rows, 0.0);
        var.resize(rows, 0.0);
        for (i, m) in mean.iter_mut().enumerate() {
            *m = crate::linalg::dot(scratch.g.row(i), &self.w) + self.y_mean;
        }
        match &self.quad {
            QuadTerm::Dense(a) => {
                diag_quad_into(lctx, &scratch.g, a, var);
                for v in var.iter_mut() {
                    *v = self.c0 - *v;
                }
            }
            QuadTerm::LowRank { diag_coef, vt } => {
                scratch.h.resize_to(rows, vt.cols);
                gemm_into(lctx, &scratch.g, vt, &mut scratch.h);
                for (i, v) in var.iter_mut().enumerate() {
                    let gi = scratch.g.row(i);
                    let hi = scratch.h.row(i);
                    let gg = crate::linalg::dot(gi, gi);
                    let hh = crate::linalg::dot(hi, hi);
                    *v = self.c0 - diag_coef * gg + hh;
                }
            }
        }
    }

    /// Allocating convenience wrapper over [`Self::predict_into`].
    #[must_use]
    pub fn predict_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        let mut scratch = OpScratch::new();
        let mut mean = Vec::new();
        let mut var = Vec::new();
        self.predict_into(lctx, &xu.data, xu.rows, &mut scratch,
                          &mut mean, &mut var);
        Prediction { mean, var }
    }

    /// Order-stable FNV-1a fingerprint of the staged numeric state
    /// (mean weights, constants, quadratic term — the exact f64 bit
    /// patterns). Two operators staged from the same fitted state hash
    /// equal; the serving layer aggregates these into the model
    /// identity reported by `/healthz` and asserted by the hot-swap
    /// tests. Not cryptographic.
    #[must_use]
    pub fn state_digest(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01B3;
        fn eat(h: &mut u64, bits: u64) {
            for b in bits.to_le_bytes() {
                *h = (*h ^ u64::from(b)).wrapping_mul(PRIME);
            }
        }
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        eat(&mut h, self.w.len() as u64);
        for &v in &self.w {
            eat(&mut h, v.to_bits());
        }
        eat(&mut h, self.y_mean.to_bits());
        eat(&mut h, self.c0.to_bits());
        match &self.quad {
            QuadTerm::Dense(a) => {
                eat(&mut h, 1);
                eat(&mut h, a.rows as u64);
                eat(&mut h, a.cols as u64);
                for &v in &a.data {
                    eat(&mut h, v.to_bits());
                }
            }
            QuadTerm::LowRank { diag_coef, vt } => {
                eat(&mut h, 2);
                eat(&mut h, diag_coef.to_bits());
                eat(&mut h, vt.rows as u64);
                eat(&mut h, vt.cols as u64);
                for &v in &vt.data {
                    eat(&mut h, v.to_bits());
                }
            }
        }
        h
    }

    /// Demote to the opt-in mixed-precision serve form (f32 storage,
    /// f64 accumulation — see [`PredictOperatorF32`]). The one lossy
    /// step of that pipeline: every staged array is rounded to f32
    /// here, once, at stage time.
    #[must_use]
    pub fn demote(&self) -> PredictOperatorF32 {
        PredictOperatorF32 {
            feat: self.feat.demote(),
            w: self.w.iter().map(|&v| v as f32).collect(),
            y_mean: self.y_mean,
            c0: self.c0,
            quad: match &self.quad {
                QuadTerm::Dense(a) => QuadTermF32::Dense(MatF32::from_mat(a)),
                QuadTerm::LowRank { diag_coef, vt } => QuadTermF32::LowRank {
                    diag_coef: *diag_coef,
                    vt: MatF32::from_mat(vt),
                },
            },
        }
    }
}

/// Relative-error budget of the mixed-precision serve path against the
/// f64 operator it was demoted from: for every query row,
/// `|meanₓ − mean| ≤ budget · max(|mean|, 1)` and
/// `|varₓ − var| ≤ budget · max(|var|, c₀)` (the `c₀` floor keeps the
/// bound meaningful where the variance nearly cancels). The storage
/// rounding is ≤2⁻²⁴ ≈ 6·10⁻⁸ relative per entry; the √p-style
/// amplification through the dots leaves ~10⁻⁶ observed on serve-sized
/// problems, so 10⁻⁴ is a ~100× safety margin. Asserted in the tests
/// below and re-measured per run by the BENCH_serve harness.
pub const F32_SERVE_REL_BUDGET: f64 = 1e-4;

/// The variance form a [`PredictOperatorF32`] evaluates — f32-stored
/// sibling of [`QuadTerm`].
#[derive(Debug, Clone)]
enum QuadTermF32 {
    /// `σ²ᵢ = c₀ − gᵢᵀ·A·gᵢ` via [`diag_quad_f32_into`].
    Dense(MatF32),
    /// `σ²ᵢ = c₀ − diag_coef·‖gᵢ‖² + ‖vtᵀgᵢ‖²` with the low-rank
    /// factor swept by widening axpys (vt: p×R, f32).
    LowRank { diag_coef: f64, vt: MatF32 },
}

/// Reusable buffers for [`PredictOperatorF32::predict_into`].
#[derive(Debug, Clone, Default)]
pub struct OpScratchF32 {
    feat: FeatureScratch,
    g: MatF32,
    /// f64 row buffer for the low-rank sweep (length R).
    h: Vec<f64>,
}

impl OpScratchF32 {
    #[must_use]
    pub fn new() -> OpScratchF32 {
        OpScratchF32::default()
    }
}

/// Mixed-precision staged predictive distribution: **f32 storage, f64
/// accumulate**. Demoted from a [`PredictOperator`] at stage time
/// ([`PredictOperator::demote`]); serves the same three-step batch
/// (feature build, mean GEMV, fused variance pass) with every staged
/// array — sources, weights, quadratic operator — stored in f32 so the
/// memory-bound predict path streams half the bytes. All reductions
/// accumulate in f64 (each f32 load widens exactly), so the only error
/// vs the f64 operator is the one-time storage rounding, budgeted at
/// [`F32_SERVE_REL_BUDGET`]. Pooled execution is bitwise-identical to
/// serial, and per-row outputs are batch-independent (padding is
/// transparent), for the same banding reasons as the f64 path.
#[derive(Debug, Clone)]
pub struct PredictOperatorF32 {
    feat: FeatureMapF32,
    w: Vec<f32>,
    y_mean: f64,
    c0: f64,
    quad: QuadTermF32,
}

impl PredictOperatorF32 {
    /// Feature dimension p.
    #[must_use]
    pub fn p(&self) -> usize {
        self.feat.p()
    }

    /// Input dimensionality d.
    #[must_use]
    pub fn dim(&self) -> usize {
        self.feat.dim()
    }

    /// Mixed-precision serve-path prediction — same contract as
    /// [`PredictOperator::predict_into`] (caller-owned outputs resized
    /// to `rows`; nothing allocated once `scratch` is warm) with the
    /// [`F32_SERVE_REL_BUDGET`] accuracy bound against the f64
    /// operator this one was demoted from.
    pub fn predict_into(
        &self,
        lctx: &LinalgCtx,
        q: &[f64],
        rows: usize,
        scratch: &mut OpScratchF32,
        mean: &mut Vec<f64>,
        var: &mut Vec<f64>,
    ) {
        self.feat.fill(lctx, q, rows, &mut scratch.g, &mut scratch.feat);
        mean.resize(rows, 0.0);
        var.resize(rows, 0.0);
        for (i, m) in mean.iter_mut().enumerate() {
            *m = dot_wide(scratch.g.row(i), &self.w) + self.y_mean;
        }
        match &self.quad {
            QuadTermF32::Dense(a) => {
                diag_quad_f32_into(lctx, &scratch.g, a, var);
                for v in var.iter_mut() {
                    *v = self.c0 - *v;
                }
            }
            QuadTermF32::LowRank { diag_coef, vt } => {
                let r = vt.cols;
                scratch.h.resize(r, 0.0);
                for (i, v) in var.iter_mut().enumerate() {
                    let gi = scratch.g.row(i);
                    scratch.h.fill(0.0);
                    for (k, &gk) in gi.iter().enumerate() {
                        axpy_wide(gk as f64, vt.row(k), &mut scratch.h);
                    }
                    let gg = dot_wide(gi, gi);
                    let hh = crate::linalg::dot(&scratch.h, &scratch.h);
                    *v = self.c0 - diag_coef * gg + hh;
                }
            }
        }
    }

    /// Allocating convenience wrapper over [`Self::predict_into`].
    #[must_use]
    pub fn predict_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        let mut scratch = OpScratchF32::new();
        let mut mean = Vec::new();
        let mut var = Vec::new();
        self.predict_into(lctx, &xu.data, xu.rows, &mut scratch,
                          &mut mean, &mut var);
        Prediction { mean, var }
    }
}

/// Explicit SPD inverse from a Cholesky factor (two banded triangular
/// solves against I), symmetrized to kill the solves' rounding skew.
fn chol_inverse(lctx: &LinalgCtx, l: &Mat) -> Mat {
    let mut inv = cho_solve_mat_ctx(lctx, l, &Mat::identity(l.rows));
    inv.symmetrize();
    inv
}

/// FGP operator: `w = α`, `A = Σ_DD⁻¹`. `l` is chol(Σ_DD + jitter).
pub fn fgp_operator(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    xd: &Mat,
    l: &Mat,
    alpha: &[f64],
    y_mean: f64,
) -> PredictOperator {
    PredictOperator {
        feat: hyp.feature_map(&[xd]),
        w: alpha.to_vec(),
        y_mean,
        c0: hyp.prior_var(),
        quad: QuadTerm::Dense(chol_inverse(lctx, l)),
    }
}

/// PITC/pPITC operator (Definition 4): `w = Σ̈_SS⁻¹ÿ_S`,
/// `A = Σ_SS⁻¹ − Σ̈_SS⁻¹`.
pub fn pitc_operator(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    sctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
    y_mean: f64,
) -> PredictOperator {
    let w = cho_solve_vec(l_g, &global.y);
    let mut a = chol_inverse(lctx, &sctx.l_ss);
    a.sub_assign(&chol_inverse(lctx, l_g));
    a.symmetrize();
    PredictOperator {
        feat: hyp.feature_map(&[&sctx.xs]),
        w,
        y_mean,
        c0: hyp.prior_var(),
        quad: QuadTerm::Dense(a),
    }
}

/// PIC/pPIC machine-m operator (Definition 5 + the DESIGN.md variance
/// erratum) over the stacked features `[k(u,S); k(u,X_m)]`. `ym` is
/// machine m's *centered* outputs.
#[allow(clippy::too_many_arguments)]
pub fn ppic_operator(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    sctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
    xm: &Mat,
    ym: &[f64],
    local: &LocalSummary,
    y_mean: f64,
) -> PredictOperator {
    let s = sctx.size();
    let b = xm.rows;
    let p = s + b;
    let k_ms = hyp.cov_cross_ctx(lctx, xm, &sctx.xs); // (B, S)
    let z = cho_solve_mat_ctx(lctx, &local.l_m, &k_ms); // Σ_mm|S⁻¹Σ_mS (B,S)
    let m_inv = chol_inverse(lctx, &local.l_m); // (B, B)
    let kss_inv = chol_inverse(lctx, &sctx.l_ss); // (S, S)
    let mut p_mat = cho_solve_mat_ctx(lctx, &sctx.l_ss, &local.s_dot);
    p_mat.add_diag(1.0); // P = I + Σ_SS⁻¹Σ̇_SS (S, S)

    let gy = cho_solve_vec(l_g, &global.y); // ĝ = Σ̈⁻¹ÿ
    let ky = cho_solve_vec(&sctx.l_ss, &local.y_dot);
    let v = cho_solve_vec(&local.l_m, ym);
    let mut w = matvec(&p_mat, &gy);
    for (wi, k) in w.iter_mut().zip(ky.iter()) {
        *wi -= k;
    }
    let zgy = matvec(&z, &gy);
    w.extend(v.iter().zip(zgy.iter()).map(|(a, b)| a - b));

    // A = [[P·Σss⁻¹, −Σss⁻¹Zᵀ], [−ZΣss⁻¹, Σ_mm|S⁻¹]] − C·Σ̈⁻¹·Cᵀ
    let a_ss = gemm(lctx, &p_mat, &kss_inv); // (S, S)
    let zk = gemm(lctx, &z, &kss_inv); // ZΣss⁻¹ (B, S)
    let mut a = Mat::zeros(p, p);
    for i in 0..s {
        a.row_mut(i)[..s].copy_from_slice(a_ss.row(i));
    }
    for i in 0..b {
        let zrow = zk.row(i);
        for j in 0..s {
            let val = -zrow[j];
            a[(s + i, j)] = val;
            a[(j, s + i)] = val;
        }
        for j in 0..b {
            a[(s + i, s + j)] = m_inv[(i, j)];
        }
    }
    // C = [P; −Z] (p × S); subtract C·Σ̈⁻¹·Cᵀ = WᵀW with W = L_g⁻¹Cᵀ.
    let mut ct = Mat::zeros(s, p); // Cᵀ
    for i in 0..s {
        let row = ct.row_mut(i);
        for j in 0..s {
            row[j] = p_mat[(j, i)];
        }
        for j in 0..b {
            row[s + j] = -z[(j, i)];
        }
    }
    let w_mat = solve_lower_mat_ctx(lctx, l_g, &ct); // (S, p)
    a.sub_assign(&gemm_tn(lctx, &w_mat, &w_mat));
    a.symmetrize();

    PredictOperator {
        feat: hyp.feature_map(&[&sctx.xs, xm]),
        w,
        y_mean,
        c0: hyp.prior_var(),
        quad: QuadTerm::Dense(a),
    }
}

/// One [`ppic_operator`] per machine block — the shared staging tail
/// of every PIC-family serve path ([`crate::gp::pic::PicGp`], the pPIC
/// facade model, [`crate::server::ServedModel`]), so the recipe lives
/// in exactly one place.
#[allow(clippy::too_many_arguments)]
pub fn ppic_operators(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    sctx: &SupportContext,
    global: &GlobalSummary,
    l_g: &Mat,
    blocks: &[(Mat, Vec<f64>, LocalSummary)],
    y_mean: f64,
) -> Vec<PredictOperator> {
    blocks
        .iter()
        .map(|(xm, ym, loc)| {
            ppic_operator(lctx, hyp, sctx, global, l_g, xm, ym, loc, y_mean)
        })
        .collect()
}

/// ICF/pICF operator (Definitions 7–9 collapsed): one weight vector
/// over all |D| features plus the rank-R low-rank variance factor.
/// `blocks[m] = (X_m, centered y_m, F_m slab)`; features follow block
/// order.
pub fn icf_operator(
    lctx: &LinalgCtx,
    hyp: &SeArd,
    blocks: &[(&Mat, &[f64], &Mat)],
    y_mean: f64,
) -> PredictOperator {
    assert!(!blocks.is_empty());
    let r = blocks[0].2.rows;
    let n: usize = blocks.iter().map(|(x, _, _)| x.rows).sum();
    let inv_sn2 = 1.0 / hyp.sn2();

    let mut sum_y = vec![0.0; r];
    let mut phi = Mat::identity(r);
    for (xm, ym, f_m) in blocks {
        // every slab must share the achieved rank: a mismatched F_m
        // would silently truncate the Φ̃ accumulation below (zip)
        assert_eq!(f_m.rows, r, "icf_operator: slab rank mismatch");
        assert_eq!(f_m.cols, xm.rows, "icf_operator: slab width");
        let fy = matvec(f_m, ym);
        for (s, v) in sum_y.iter_mut().zip(fy.iter()) {
            *s += v;
        }
        let ff = gemm_nt(lctx, f_m, f_m);
        for (p, &q) in phi.data.iter_mut().zip(ff.data.iter()) {
            *p += inv_sn2 * q;
        }
    }
    let l_phi = cholesky_blocked(lctx, &phi).expect("Φ̃ not SPD");
    let ydd = cho_solve_vec(&l_phi, &sum_y); // ÿ = Φ̃⁻¹Σẏ

    let mut w = Vec::with_capacity(n);
    let mut f_full = Mat::zeros(r, n);
    let mut col = 0;
    for (_, ym, f_m) in blocks {
        let ft_y = matvec_t(f_m, &ydd); // F_mᵀÿ (B_m)
        w.extend(
            ym.iter()
                .zip(ft_y.iter())
                .map(|(y, t)| inv_sn2 * y - inv_sn2 * inv_sn2 * t),
        );
        for t in 0..r {
            f_full.row_mut(t)[col..col + f_m.cols]
                .copy_from_slice(f_m.row(t));
        }
        col += f_m.cols;
    }
    let mut v = solve_lower_mat_ctx(lctx, &l_phi, &f_full); // (R, n)
    v.scale(inv_sn2);

    let xs: Vec<&Mat> = blocks.iter().map(|(x, _, _)| *x).collect();
    PredictOperator {
        feat: hyp.feature_map(&xs),
        w,
        y_mean,
        c0: hyp.prior_var(),
        quad: QuadTerm::LowRank { diag_coef: inv_sn2, vt: v.transpose() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::summaries::{
        chol_global, global_summary, local_summary, ppic_predict,
        ppitc_predict,
    };
    use crate::testkit::assert_all_close;
    use crate::testkit::prop::{prop_check, Gen};

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// pPITC operator ≡ the seed solve-based ppitc_predict ≤1e-12.
    #[test]
    fn pitc_operator_matches_ppitc_predict() {
        prop_check("op-pitc", 10, |g| {
            let d = g.usize_in(1, 3);
            let (s, b, u) =
                (g.usize_in(2, 6), g.usize_in(3, 9), g.usize_in(1, 7));
            let hyp = rand_hyp(g, d);
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xm = Mat::from_vec(b, d, g.uniform_vec(b * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let ym = g.normal_vec(b);
            let sctx = SupportContext::new(&hyp, &xs);
            let loc = local_summary(&hyp, &xm, &ym, &sctx);
            let glob = global_summary(&sctx, &[&loc]);
            let l_g = chol_global(&glob);
            let lctx = LinalgCtx::serial();

            let op = pitc_operator(&lctx, &hyp, &sctx, &glob, &l_g, 0.0);
            let got = op.predict_ctx(&lctx, &xu);
            let want = ppitc_predict(&hyp, &xu, &sctx, &glob, &l_g);
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        });
    }

    /// pPIC operator ≡ the seed solve-based ppic_predict ≤1e-12.
    #[test]
    fn ppic_operator_matches_ppic_predict() {
        prop_check("op-ppic", 10, |g| {
            let d = g.usize_in(1, 3);
            let (s, b, u) =
                (g.usize_in(2, 6), g.usize_in(3, 9), g.usize_in(1, 7));
            let hyp = rand_hyp(g, d);
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xm = Mat::from_vec(b, d, g.uniform_vec(b * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let ym = g.normal_vec(b);
            let sctx = SupportContext::new(&hyp, &xs);
            let loc = local_summary(&hyp, &xm, &ym, &sctx);
            let glob = global_summary(&sctx, &[&loc]);
            let l_g = chol_global(&glob);
            let lctx = LinalgCtx::serial();

            let op = ppic_operator(&lctx, &hyp, &sctx, &glob, &l_g, &xm,
                                   &ym, &loc, 0.0);
            assert_eq!(op.p(), s + b);
            let got = op.predict_ctx(&lctx, &xu);
            let want = ppic_predict(&hyp, &xu, &xm, &ym, &loc, &sctx,
                                    &glob, &l_g);
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        });
    }

    /// ICF operator ≡ the Definition 8/9 component pipeline ≤1e-12.
    #[test]
    fn icf_operator_matches_component_pipeline() {
        use crate::gp::summaries::{icf_finalize, icf_global, icf_local,
                                   IcfLocalSummary};
        prop_check("op-icf", 10, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 3);
            let per = g.usize_in(2, 5);
            let u = g.usize_in(1, 6);
            let r = g.usize_in(1, 4);
            let hyp = rand_hyp(g, d);
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let mut blocks = Vec::new();
            for _ in 0..m {
                let xm =
                    Mat::from_vec(per, d, g.uniform_vec(per * d, -2.0, 2.0));
                let ym = g.normal_vec(per);
                let f_m = Mat::from_vec(r, per, g.normal_vec(r * per));
                blocks.push((xm, ym, f_m));
            }
            // oracle: Definition 6–9 pipeline
            let locals: Vec<IcfLocalSummary> = blocks
                .iter()
                .map(|(xm, ym, f_m)| icf_local(&hyp, xm, ym, &xu, f_m))
                .collect();
            let refs: Vec<_> = locals.iter().collect();
            let glob = icf_global(&hyp, &refs);
            let comps: Vec<Prediction> = blocks
                .iter()
                .zip(locals.iter())
                .map(|((xm, ym, _), loc)| {
                    crate::gp::summaries::icf_predict_component(
                        &hyp, &xu, xm, ym, &loc.s_dot, &glob)
                })
                .collect();
            let crefs: Vec<&Prediction> = comps.iter().collect();
            let want = icf_finalize(&hyp, u, &crefs);

            let lctx = LinalgCtx::serial();
            let brefs: Vec<(&Mat, &[f64], &Mat)> = blocks
                .iter()
                .map(|(x, y, f)| (x, y.as_slice(), f))
                .collect();
            let op = icf_operator(&lctx, &hyp, &brefs, 0.0);
            let got = op.predict_ctx(&lctx, &xu);
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        });
    }

    /// The demoted f32 operator stays within [`F32_SERVE_REL_BUDGET`]
    /// of the f64 operator it came from, for both variance forms:
    /// Dense (pPIC) and LowRank (ICF).
    #[test]
    fn f32_operator_within_budget_of_f64() {
        let mut rng = crate::util::Pcg64::seed(71);
        let d = 2;
        let (s, b, u) = (5, 10, 13);
        let hyp = SeArd::isotropic(d, 0.8, 1.0, 0.1);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xm = Mat::from_vec(b, d, rng.normals(b * d));
        let ym = rng.normals(b);
        let sctx = SupportContext::new(&hyp, &xs);
        let loc = local_summary(&hyp, &xm, &ym, &sctx);
        let glob = global_summary(&sctx, &[&loc]);
        let l_g = chol_global(&glob);
        let lctx = LinalgCtx::serial();
        let xu = Mat::from_vec(u, d, rng.normals(u * d));

        let check = |op: &PredictOperator| {
            let c0 = hyp.prior_var();
            let want = op.predict_ctx(&lctx, &xu);
            let got = op.demote().predict_ctx(&lctx, &xu);
            for i in 0..u {
                let m_tol = F32_SERVE_REL_BUDGET * want.mean[i].abs().max(1.0);
                assert!(
                    (got.mean[i] - want.mean[i]).abs() <= m_tol,
                    "mean row {i}: {} vs {}", got.mean[i], want.mean[i]
                );
                let v_tol = F32_SERVE_REL_BUDGET * want.var[i].abs().max(c0);
                assert!(
                    (got.var[i] - want.var[i]).abs() <= v_tol,
                    "var row {i}: {} vs {}", got.var[i], want.var[i]
                );
            }
        };
        // Dense quad form
        check(&ppic_operator(&lctx, &hyp, &sctx, &glob, &l_g, &xm, &ym,
                             &loc, 0.4));
        // LowRank quad form
        let r = 3;
        let f_m = Mat::from_vec(r, b, rng.normals(r * b));
        check(&icf_operator(&lctx, &hyp,
                            &[(&xm, ym.as_slice(), &f_m)], 0.4));
    }

    /// f32 operator predictions are bitwise pooled ≡ serial, and its
    /// scratch reuse matches fresh buffers exactly.
    #[test]
    fn f32_operator_pooled_bitwise_and_scratch_reuse() {
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        let mut rng = crate::util::Pcg64::seed(72);
        let d = 2;
        let (s, b) = (5, 12);
        let hyp = SeArd::isotropic(d, 0.8, 1.1, 0.07);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xm = Mat::from_vec(b, d, rng.normals(b * d));
        let ym = rng.normals(b);
        let sctx = SupportContext::new(&hyp, &xs);
        let loc = local_summary(&hyp, &xm, &ym, &sctx);
        let glob = global_summary(&sctx, &[&loc]);
        let l_g = chol_global(&glob);
        let serial = LinalgCtx::serial();
        let op = ppic_operator(&serial, &hyp, &sctx, &glob, &l_g, &xm,
                               &ym, &loc, 0.5)
            .demote();
        let xu = Mat::from_vec(9, d, rng.normals(9 * d));
        let want = op.predict_ctx(&serial, &xu);
        let pooled = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
        let got = op.predict_ctx(&pooled, &xu);
        assert_eq!(want.mean, got.mean);
        assert_eq!(want.var, got.var);

        let mut scratch = OpScratchF32::new();
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        for rows in [4usize, 1, 9, 4] {
            let q = rng.normals(rows * d);
            op.predict_into(&serial, &q, rows, &mut scratch, &mut mean,
                            &mut var);
            let fresh =
                op.predict_ctx(&serial, &Mat::from_vec(rows, d, q));
            assert_eq!(mean, fresh.mean, "rows={rows}");
            assert_eq!(var, fresh.var, "rows={rows}");
        }
    }

    /// Operator predictions are bitwise pooled ≡ serial (build and
    /// predict), and predict_into reuses scratch without drift.
    #[test]
    fn operator_pooled_bitwise_and_scratch_reuse() {
        use crate::util::pool::ThreadPool;
        use std::sync::Arc;
        let mut rng = crate::util::Pcg64::seed(41);
        let d = 2;
        let (s, b) = (5, 12);
        let hyp = SeArd::isotropic(d, 0.8, 1.1, 0.07);
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xm = Mat::from_vec(b, d, rng.normals(b * d));
        let ym = rng.normals(b);
        let sctx = SupportContext::new(&hyp, &xs);
        let loc = local_summary(&hyp, &xm, &ym, &sctx);
        let glob = global_summary(&sctx, &[&loc]);
        let l_g = chol_global(&glob);

        let serial = LinalgCtx::serial();
        let pooled = LinalgCtx::pooled(Arc::new(ThreadPool::new(3)));
        let op_s = ppic_operator(&serial, &hyp, &sctx, &glob, &l_g, &xm,
                                 &ym, &loc, 0.5);
        let op_p = ppic_operator(&pooled, &hyp, &sctx, &glob, &l_g, &xm,
                                 &ym, &loc, 0.5);
        let xu = Mat::from_vec(9, d, rng.normals(9 * d));
        let want = op_s.predict_ctx(&serial, &xu);
        let got = op_p.predict_ctx(&pooled, &xu);
        assert_eq!(want.mean, got.mean);
        assert_eq!(want.var, got.var);

        // scratch reuse across shapes: identical to fresh buffers
        let mut scratch = OpScratch::new();
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        for rows in [4usize, 1, 9, 4] {
            let q = rng.normals(rows * d);
            op_s.predict_into(&serial, &q, rows, &mut scratch, &mut mean,
                              &mut var);
            let fresh =
                op_s.predict_ctx(&serial, &Mat::from_vec(rows, d, q));
            assert_eq!(mean, fresh.mean, "rows={rows}");
            assert_eq!(var, fresh.var, "rows={rows}");
        }
    }
}
