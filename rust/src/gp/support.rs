//! Support-set selection via the differential entropy score criterion
//! (Lawrence et al. 2003), as prescribed after Definition 2: greedily add
//! the candidate with the largest posterior variance Σ_xx|S.
//!
//! Key identity: the greedy max-posterior-variance rule is exactly the
//! diagonal-pivoting rule of incomplete Cholesky — after k selections the
//! residual diagonal of the candidate Gram matrix *is* the vector of
//! posterior variances given the selected set. So selection reuses the
//! pivoted ICF machinery and costs O(|S|²·n_candidates) instead of
//! refitting a GP per step.

use crate::gp::icf_gp::GramSource;
use crate::kernel::SeArd;
use crate::linalg::{icf, Mat};
use crate::util::Pcg64;

/// Greedily select `size` support inputs from `candidates` (rows).
/// Returns the selected row indices in selection order.
pub fn select_support_entropy(
    hyp: &SeArd,
    candidates: &Mat,
    size: usize,
) -> Vec<usize> {
    assert!(size <= candidates.rows, "support larger than candidate pool");
    let src = GramSource { hyp, x: candidates };
    // tol 0: keep pivoting even when residuals get small; pivots are the
    // greedy max-variance picks.
    let factor = icf(&src, size, 0.0);
    factor.pivots
}

/// Random selection baseline (used by ablations).
pub fn select_support_random(
    n_candidates: usize,
    size: usize,
    rng: &mut Pcg64,
) -> Vec<usize> {
    rng.sample_indices(n_candidates, size)
}

/// Select support inputs from a candidate pool, returning the actual
/// support matrix (convenience over [`select_support_entropy`]).
pub fn support_matrix(hyp: &SeArd, candidates: &Mat, size: usize) -> Mat {
    let idx = select_support_entropy(hyp, candidates, size);
    candidates.select_rows(&idx)
}

/// The Section-6 support recipe, in one place (shared by the `api`
/// facade's `support_size` resolution and the sweep harness): draw a
/// bounded random candidate pool of `min(8·size, n)` training rows,
/// then greedily entropy-select `size` of them. `size` is clamped to
/// the training size.
pub fn support_from_pool(hyp: &SeArd, xd: &Mat, size: usize,
                         rng: &mut Pcg64) -> Mat {
    let size = size.min(xd.rows);
    let n_cand = xd.rows.min(size * 8).max(size);
    let cand_idx = rng.sample_indices(xd.rows, n_cand);
    support_matrix(hyp, &xd.select_rows(&cand_idx), size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::summaries::SupportContext;
    use crate::linalg::solve_lower_mat;

    /// Noise-free posterior variance Σ_xx|S of each row of `x` given the
    /// support set — the selection criterion itself.
    fn posterior_var(hyp: &SeArd, xs: &Mat, x: &Mat) -> Vec<f64> {
        let ctx = SupportContext::new(hyp, xs);
        let k_xs = hyp.cov_cross(x, &ctx.xs);
        let w = solve_lower_mat(&ctx.l_ss, &k_xs.transpose());
        (0..x.rows)
            .map(|i| {
                let t: f64 = (0..xs.rows).map(|r| w[(r, i)] * w[(r, i)]).sum();
                hyp.sf2() - t
            })
            .collect()
    }

    #[test]
    fn selection_is_distinct_and_in_range() {
        let mut rng = Pcg64::seed(1);
        let hyp = SeArd::isotropic(2, 0.7, 1.0, 1e-3);
        let x = Mat::from_vec(30, 2, rng.normals(60));
        let idx = select_support_entropy(&hyp, &x, 10);
        assert_eq!(idx.len(), 10);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(idx.iter().all(|&i| i < 30));
    }

    #[test]
    fn first_pick_spreads_coverage() {
        // on a 1-D line, greedy entropy selection spreads points out:
        // max pairwise gap of selected set is far below the un-spread
        // worst case.
        let n = 50;
        let hyp = SeArd::isotropic(1, 0.5, 1.0, 1e-3);
        let x = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.1).collect());
        let idx = select_support_entropy(&hyp, &x, 8);
        let mut coords: Vec<f64> = idx.iter().map(|&i| x[(i, 0)]).collect();
        coords.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // coverage: every data point within 1.0 of a support point
        for i in 0..n {
            let xi = x[(i, 0)];
            let min_dist = coords
                .iter()
                .map(|c| (c - xi).abs())
                .fold(f64::INFINITY, f64::min);
            assert!(min_dist < 1.0, "point {xi} uncovered");
        }
    }

    #[test]
    fn entropy_beats_random_on_clustered_data() {
        // clustered data: random selection oversamples dense clusters;
        // entropy selection covers all clusters. Compare max residual
        // posterior variance over the pool.
        let mut rng = Pcg64::seed(9);
        let n = 60;
        let mut x = Mat::zeros(n, 1);
        for i in 0..n {
            // three clusters at 0, 10, 20 with sizes 50, 5, 5
            let c = if i < 50 { 0.0 } else if i < 55 { 10.0 } else { 20.0 };
            x[(i, 0)] = c + rng.normal() * 0.2;
        }
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 1e-3);

        let max_resid = |idx: &[usize]| -> f64 {
            let xs = x.select_rows(idx);
            let ctx = SupportContext::new(&hyp, &xs);
            let k_xs = hyp.cov_cross(&x, &ctx.xs);
            let w = solve_lower_mat(&ctx.l_ss, &k_xs.transpose());
            (0..n)
                .map(|i| {
                    let t: f64 =
                        (0..idx.len()).map(|r| w[(r, i)] * w[(r, i)]).sum();
                    hyp.sf2() - t
                })
                .fold(0.0f64, f64::max)
        };

        let ent = select_support_entropy(&hyp, &x, 6);
        let mut rand_worst: f64 = 0.0;
        for seed in 0..5 {
            let r = select_support_random(n, 6, &mut Pcg64::seed(100 + seed));
            rand_worst += max_resid(&r);
        }
        rand_worst /= 5.0;
        let ent_resid = max_resid(&ent);
        assert!(
            ent_resid < rand_worst,
            "entropy {ent_resid:.4} vs random-avg {rand_worst:.4}"
        );
    }

    #[test]
    fn support_matrix_rows_match_selection() {
        let mut rng = Pcg64::seed(2);
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 1e-2);
        let x = Mat::from_vec(15, 2, rng.normals(30));
        let idx = select_support_entropy(&hyp, &x, 5);
        let xs = support_matrix(&hyp, &x, 5);
        for (k, &i) in idx.iter().enumerate() {
            assert_eq!(xs.row(k), x.row(i));
        }
    }

    #[test]
    fn random_baseline_distinct() {
        let mut rng = Pcg64::seed(3);
        let idx = select_support_random(20, 7, &mut rng);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 7);
    }

    /// The greedy pick order matches explicit max-posterior-variance
    /// re-evaluation (the ICF-pivot identity the module relies on).
    /// The explicit criterion here is noise-free (Σ_xx|S of the latent
    /// function), matching the selection's pivoted-ICF formulation; ties
    /// break toward the smallest index like linalg::icf.
    #[test]
    fn pivots_match_explicit_greedy() {
        let mut rng = Pcg64::seed(4);
        // noise-free context for the explicit recomputation: sn2 ~ 0
        let hyp = SeArd::isotropic(1, 0.6, 1.3, 1e-13);
        let x = Mat::from_vec(12, 1, rng.normals(12));
        let picks = select_support_entropy(&hyp, &x, 4);
        let mut chosen: Vec<usize> = Vec::new();
        for _ in 0..4 {
            let mut best = usize::MAX;
            let mut best_v = f64::NEG_INFINITY;
            for i in 0..12 {
                if chosen.contains(&i) {
                    continue;
                }
                let v = if chosen.is_empty() {
                    hyp.sf2()
                } else {
                    posterior_var(&hyp, &x.select_rows(&chosen),
                                  &x.select_rows(&[i]))[0]
                };
                if v > best_v {
                    best_v = v;
                    best = i;
                }
            }
            chosen.push(best);
        }
        assert_eq!(picks, chosen);
    }
}
