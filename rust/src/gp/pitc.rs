//! Centralized PITC approximation (Quiñonero-Candela & Rasmussen 2005),
//! eqs. (9)-(11) — the sequential counterpart of pPITC (Theorem 1).
//!
//! Implemented as the same block-summary computation the parallel
//! protocol distributes, executed serially on one machine: this is what
//! Table 1's PITC row costs (O(|S|²|D| + |D|(|D|/M)²)) and it is
//! numerically *identical* to pPITC by Theorem 1 (tested against the
//! literal eqs. (9)-(10) below).

use std::sync::OnceLock;

use super::predictor::{pitc_operator, PredictOperator};
use super::summaries::{
    global_summary, ppitc_predict_ctx, try_chol_global_ctx,
    try_local_summary_ctx, GlobalSummary, SupportContext,
};
use super::Prediction;
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};

/// Fitted centralized PITC model.
#[derive(Debug, Clone)]
pub struct PitcGp {
    hyp: SeArd,
    ctx: SupportContext,
    global: GlobalSummary,
    l_g: Mat,
    pub y_mean: f64,
    /// Serve-path operator (`w = Σ̈⁻¹ÿ`, `A = Σ_SS⁻¹ − Σ̈_SS⁻¹`),
    /// built lazily on first [`PitcGp::predictor`] call.
    op: OnceLock<PredictOperator>,
}

impl PitcGp {
    /// Fit from data partitioned into `d_blocks` (Definition 1).
    pub fn fit(
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
    ) -> PitcGp {
        PitcGp::fit_ctx(&LinalgCtx::serial(), hyp, xd, y, xs, d_blocks)
    }

    /// [`PitcGp::fit`] with explicit linalg execution context (the
    /// sweep harness passes the cluster executor's pooled ctx).
    pub fn fit_ctx(
        lctx: &LinalgCtx,
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
    ) -> PitcGp {
        PitcGp::try_fit_ctx(lctx, hyp, xd, y, xs, d_blocks)
            .unwrap_or_else(|e| panic!("PITC fit: covariance not SPD: {e}"))
    }

    /// Fallible [`PitcGp::fit_ctx`] — the facade ([`crate::api`])
    /// reports non-SPD covariances as typed errors instead of panicking.
    pub fn try_fit_ctx(
        lctx: &LinalgCtx,
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        xs: &Mat,
        d_blocks: &[Vec<usize>],
    ) -> Result<PitcGp, crate::linalg::cholesky::NotSpd> {
        assert_eq!(xd.rows, y.len());
        let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
        let ctx = SupportContext::try_new_ctx(lctx, hyp, xs)?;
        let mut locals = Vec::with_capacity(d_blocks.len());
        for blk in d_blocks {
            let xm = xd.select_rows(blk);
            let ym: Vec<f64> = blk.iter().map(|&i| y[i] - y_mean).collect();
            locals.push(try_local_summary_ctx(lctx, hyp, &xm, &ym, &ctx)?);
        }
        let refs: Vec<_> = locals.iter().collect();
        let global = global_summary(&ctx, &refs);
        let l_g = try_chol_global_ctx(lctx, &global)?;
        Ok(PitcGp {
            hyp: hyp.clone(),
            ctx,
            global,
            l_g,
            y_mean,
            op: OnceLock::new(),
        })
    }

    /// Predict any test set (Definition 4 applied to the whole U).
    pub fn predict(&self, xu: &Mat) -> Prediction {
        self.predict_ctx(&LinalgCtx::serial(), xu)
    }

    /// [`PitcGp::predict`] with explicit linalg execution context.
    pub fn predict_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        let mut p = ppitc_predict_ctx(lctx, &self.hyp, xu, &self.ctx,
                                      &self.global, &self.l_g);
        p.shift_mean(self.y_mean);
        p
    }

    /// The staged predictive operator (built on first call, cached):
    /// Definition 4 as one GEMV + one fused quadratic-form pass.
    /// Equal to [`PitcGp::predict`] ≤1e-12 (tested).
    pub fn predictor(&self, lctx: &LinalgCtx) -> &PredictOperator {
        self.op.get_or_init(|| {
            pitc_operator(lctx, &self.hyp, &self.ctx, &self.global,
                          &self.l_g, self.y_mean)
        })
    }

    /// Serve-path prediction through [`PitcGp::predictor`].
    pub fn predict_fast_ctx(&self, lctx: &LinalgCtx, xu: &Mat) -> Prediction {
        self.predictor(lctx).predict_ctx(lctx, xu)
    }
}

/// Literal transcription of eqs. (9)-(11) — O(|D|³) dense oracle used
/// only by tests (Theorem 1 ground truth).
pub fn pitc_direct_oracle(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    d_blocks: &[Vec<usize>],
) -> Prediction {
    use crate::linalg::{cho_solve_mat, cho_solve_vec, cholesky, matmul, matvec};
    let n = xd.rows;
    let y_mean = y.iter().sum::<f64>() / n as f64;
    let centered: Vec<f64> = y.iter().map(|v| v - y_mean).collect();

    let ctx = SupportContext::new(hyp, xs);
    let k_ds = hyp.cov_cross(xd, xs);
    let k_us = hyp.cov_cross(xu, xs);
    // Γ_BB' = Σ_BS Σ_SS⁻¹ Σ_SB'
    let kss_inv_ksd = cho_solve_mat(&ctx.l_ss, &k_ds.transpose()); // (S, n)
    let gamma_dd = matmul(&k_ds, &kss_inv_ksd); // (n, n)
    let gamma_ud = matmul(&k_us, &kss_inv_ksd); // (U, n)

    // Λ = blockdiag(Σ_DmDm|S) with the same jitter policy as the graphs
    let sigma_dd = hyp.cov_same(xd, false);
    let mut a = gamma_dd.clone();
    for blk in d_blocks {
        for &i in blk {
            for &j in blk {
                a[(i, j)] = sigma_dd[(i, j)];
            }
            a[(i, i)] += hyp.jitter();
        }
    }
    let l_a = cholesky(&a).expect("Γ_DD + Λ not SPD");

    let mut mean = matvec(&gamma_ud, &cho_solve_vec(&l_a, &centered));
    for m in mean.iter_mut() {
        *m += y_mean;
    }
    let w = cho_solve_mat(&l_a, &gamma_ud.transpose()); // (n, U)
    let prior = hyp.prior_var();
    let var = (0..xu.rows)
        .map(|i| {
            let t: f64 = (0..n).map(|r| gamma_ud[(i, r)] * w[(r, i)]).sum();
            prior - t
        })
        .collect();
    Prediction { mean, var }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// Theorem 1 (centralized side): the block-summary implementation
    /// equals the literal eqs. (9)-(10).
    #[test]
    fn theorem1_block_equals_direct() {
        prop_check("thm1-pitc", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let per = g.usize_in(2, 5);
            let n = m * per;
            let s = g.usize_in(2, 5);
            let u = g.usize_in(1, 6);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let blocks = random_partition(n, m, g.rng());

            let model = PitcGp::fit(&hyp, &xd, &y, &xs, &blocks);
            let got = model.predict(&xu);
            let want = pitc_direct_oracle(&hyp, &xd, &y, &xs, &xu, &blocks);
            assert_all_close(&got.mean, &want.mean, 1e-6, 1e-6);
            assert_all_close(&got.var, &want.var, 1e-6, 1e-6);
        });
    }

    /// The staged operator path reproduces the seed solve-based
    /// Definition-4 predict to ≤1e-12.
    #[test]
    fn fast_path_matches_solve_path() {
        prop_check("pitc-fast-vs-solve", 8, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let s = g.usize_in(2, 5);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xu = Mat::from_vec(5, d, g.uniform_vec(5 * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let blocks = random_partition(n, m, g.rng());
            let model = PitcGp::fit(&hyp, &xd, &y, &xs, &blocks);
            let want = model.predict(&xu);
            let got = model.predict_fast_ctx(&crate::linalg::LinalgCtx::serial(), &xu);
            assert_all_close(&got.mean, &want.mean, 1e-12, 1e-12);
            assert_all_close(&got.var, &want.var, 1e-12, 1e-12);
        });
    }

    /// With S = D and sn2 → 0, PITC collapses to FGP. (The paper-literal
    /// Σ_SS = K_SS + sn2·I convention makes the classical S=D identity
    /// only approximate, with O(sn2) error — hence the tiny noise here.)
    #[test]
    fn single_block_reasonable() {
        let mut rng = crate::util::Pcg64::seed(8);
        let n = 12;
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 1e-6);
        let xd = Mat::from_vec(n, 1, (0..n).map(|i| i as f64 * 0.3).collect());
        let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
        let _ = &mut rng;
        // support set = training inputs → PITC == FGP exactly (S = D)
        let blocks = vec![(0..n).collect::<Vec<_>>()];
        let model = PitcGp::fit(&hyp, &xd, &y, &xd, &blocks);
        let fgp = crate::gp::FullGp::fit(&hyp, &xd, &y);
        let xu = Mat::from_vec(3, 1, vec![0.45, 1.1, 2.2]);
        let got = model.predict(&xu);
        let want = fgp.predict(&xu);
        assert_all_close(&got.mean, &want.mean, 1e-4, 1e-4);
    }

    /// More machines (smaller blocks) degrade the approximation
    /// monotonically in typical cases — here we just check it stays sane.
    #[test]
    fn predictions_bounded() {
        let mut rng = crate::util::Pcg64::seed(11);
        let n = 24;
        let hyp = SeArd::isotropic(2, 1.0, 1.0, 0.05);
        let xd = Mat::from_vec(n, 2, rng.normals(n * 2));
        let y = rng.normals(n);
        let xs = Mat::from_vec(6, 2, rng.normals(12));
        let blocks = random_partition(n, 4, &mut rng);
        let model = PitcGp::fit(&hyp, &xd, &y, &xs, &blocks);
        let xu = Mat::from_vec(10, 2, rng.normals(20));
        let pred = model.predict(&xu);
        for i in 0..10 {
            assert!(pred.mean[i].is_finite());
            assert!(pred.var[i] > 0.0 && pred.var[i] <= hyp.prior_var() + 1e-9);
        }
    }
}
