//! The [`Backend`] trait: the six block-level operations every protocol
//! step dispatches through, each corresponding 1:1 to an AOT graph.
//!
//! * [`NativeBackend`] — pure-rust (`gp::summaries`), any shapes; used by
//!   the simulator sweeps and as the numerical reference.
//! * [`crate::runtime::PjrtBackend`] — executes the HLO-text artifacts on
//!   the PJRT CPU client; shapes pinned by the manifest; the serving hot
//!   path. Integration tests assert the two agree.

use crate::gp::summaries::{
    self, GlobalSummary, IcfGlobalSummary, IcfLocalSummary, LocalSummary,
    SupportContext,
};
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;

/// Block-level compute operations (Definitions 2–8 of the paper).
///
/// Conventions: `ym` is already centered; every call is self-contained
/// (stateless w.r.t. previous calls) so implementations are trivially
/// shareable across simulated nodes.
pub trait Backend: Send + Sync {
    /// Definition 2: `(ẏ_S, Σ̇_SS, chol(Σ_mm|S))`.
    fn local_summary(&self, hyp: &SeArd, xm: &Mat, ym: &[f64], xs: &Mat)
        -> LocalSummary;

    /// Definition 4: pPITC block prediction from the global summary.
    fn ppitc_predict(&self, hyp: &SeArd, xu: &Mat, xs: &Mat,
                     glob: &GlobalSummary) -> Prediction;

    /// Definition 5: pPIC block prediction (global + machine-local data).
    #[allow(clippy::too_many_arguments)]
    fn ppic_predict(&self, hyp: &SeArd, xu: &Mat, xs: &Mat, xm: &Mat,
                    ym: &[f64], local: &LocalSummary, glob: &GlobalSummary)
                    -> Prediction;

    /// [`Backend::ppitc_predict`] with the support context and global
    /// Cholesky already staged: every machine already holds Σ_SS (it
    /// computed its local summary from it) and the broadcast global
    /// summary, so nothing about the hoist changes the protocol's
    /// traffic — it only stops re-factorizing two |S|×|S| matrices per
    /// block prediction. The **default delegates to the unstaged
    /// virtual call** (dropping the staged factors), so backends that
    /// only override [`Backend::ppitc_predict`] — the PJRT AOT-graph
    /// path — keep executing their own implementation; backends that
    /// can exploit the staged factors (native) override this too.
    fn ppitc_predict_staged(&self, hyp: &SeArd, xu: &Mat,
                            ctx: &SupportContext, glob: &GlobalSummary,
                            l_g: &Mat) -> Prediction {
        let _ = l_g;
        self.ppitc_predict(hyp, xu, &ctx.xs, glob)
    }

    /// [`Backend::ppic_predict`] with the support context and global
    /// Cholesky already staged (same override contract as
    /// [`Backend::ppitc_predict_staged`]).
    #[allow(clippy::too_many_arguments)]
    fn ppic_predict_staged(&self, hyp: &SeArd, xu: &Mat,
                           ctx: &SupportContext, xm: &Mat, ym: &[f64],
                           local: &LocalSummary, glob: &GlobalSummary,
                           l_g: &Mat) -> Prediction {
        let _ = l_g;
        self.ppic_predict(hyp, xu, &ctx.xs, xm, ym, local, glob)
    }

    /// Definition 6: ICF local summary from the machine's factor slab.
    fn icf_local(&self, hyp: &SeArd, xm: &Mat, ym: &[f64], xu: &Mat,
                 f_m: &Mat) -> IcfLocalSummary;

    /// Definition 7: ICF global summary from summed local summaries.
    fn icf_global(&self, hyp: &SeArd, sum_y: &[f64], sum_s: &Mat,
                  sum_phi: &Mat) -> IcfGlobalSummary;

    /// Definition 8: machine m's predictive component.
    fn icf_predict(&self, hyp: &SeArd, xu: &Mat, xm: &Mat, ym: &[f64],
                   s_dot_m: &Mat, glob: &IcfGlobalSummary) -> Prediction;

    /// Human-readable backend name (logs/metrics).
    fn name(&self) -> &'static str;
}

/// Pure-rust backend delegating to [`crate::gp::summaries`].
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn local_summary(&self, hyp: &SeArd, xm: &Mat, ym: &[f64], xs: &Mat)
        -> LocalSummary
    {
        let ctx = SupportContext::new(hyp, xs);
        summaries::local_summary(hyp, xm, ym, &ctx)
    }

    fn ppitc_predict(&self, hyp: &SeArd, xu: &Mat, xs: &Mat,
                     glob: &GlobalSummary) -> Prediction
    {
        let ctx = SupportContext::new(hyp, xs);
        let l_g = summaries::chol_global(glob);
        summaries::ppitc_predict(hyp, xu, &ctx, glob, &l_g)
    }

    fn ppic_predict(&self, hyp: &SeArd, xu: &Mat, xs: &Mat, xm: &Mat,
                    ym: &[f64], local: &LocalSummary, glob: &GlobalSummary)
                    -> Prediction
    {
        let ctx = SupportContext::new(hyp, xs);
        let l_g = summaries::chol_global(glob);
        summaries::ppic_predict(hyp, xu, xm, ym, local, &ctx, glob, &l_g)
    }

    fn ppitc_predict_staged(&self, hyp: &SeArd, xu: &Mat,
                            ctx: &SupportContext, glob: &GlobalSummary,
                            l_g: &Mat) -> Prediction {
        summaries::ppitc_predict(hyp, xu, ctx, glob, l_g)
    }

    fn ppic_predict_staged(&self, hyp: &SeArd, xu: &Mat,
                           ctx: &SupportContext, xm: &Mat, ym: &[f64],
                           local: &LocalSummary, glob: &GlobalSummary,
                           l_g: &Mat) -> Prediction {
        summaries::ppic_predict(hyp, xu, xm, ym, local, ctx, glob, l_g)
    }

    fn icf_local(&self, hyp: &SeArd, xm: &Mat, ym: &[f64], xu: &Mat,
                 f_m: &Mat) -> IcfLocalSummary
    {
        summaries::icf_local(hyp, xm, ym, xu, f_m)
    }

    fn icf_global(&self, hyp: &SeArd, sum_y: &[f64], sum_s: &Mat,
                  sum_phi: &Mat) -> IcfGlobalSummary
    {
        // repackage the pre-summed inputs as a single pseudo-local
        let pseudo = IcfLocalSummary {
            y_dot: sum_y.to_vec(),
            s_dot: sum_s.clone(),
            phi: sum_phi.clone(),
        };
        summaries::icf_global(hyp, &[&pseudo])
    }

    fn icf_predict(&self, hyp: &SeArd, xu: &Mat, xm: &Mat, ym: &[f64],
                   s_dot_m: &Mat, glob: &IcfGlobalSummary) -> Prediction
    {
        summaries::icf_predict_component(hyp, xu, xm, ym, s_dot_m, glob)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::summaries::global_summary;
    use crate::testkit::assert_all_close;
    use crate::util::Pcg64;

    /// The backend indirection must be numerically identical to calling
    /// gp::summaries directly.
    #[test]
    fn native_backend_matches_direct_calls() {
        let mut rng = Pcg64::seed(21);
        let d = 2;
        let (b, s, u) = (6, 4, 5);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.05);
        let xm = Mat::from_vec(b, d, rng.normals(b * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let ym = rng.normals(b);

        let be = NativeBackend;
        let loc = be.local_summary(&hyp, &xm, &ym, &xs);
        let ctx = SupportContext::new(&hyp, &xs);
        let loc2 = summaries::local_summary(&hyp, &xm, &ym, &ctx);
        assert_all_close(&loc.y_dot, &loc2.y_dot, 1e-14, 1e-14);
        assert!(loc.s_dot.max_abs_diff(&loc2.s_dot) < 1e-14);

        let glob = global_summary(&ctx, &[&loc2]);
        let p1 = be.ppitc_predict(&hyp, &xu, &xs, &glob);
        let l_g = summaries::chol_global(&glob);
        let p2 = summaries::ppitc_predict(&hyp, &xu, &ctx, &glob, &l_g);
        assert_all_close(&p1.mean, &p2.mean, 1e-14, 1e-14);
        assert_all_close(&p1.var, &p2.var, 1e-14, 1e-14);

        let p3 = be.ppic_predict(&hyp, &xu, &xs, &xm, &ym, &loc, &glob);
        let p4 = summaries::ppic_predict(&hyp, &xu, &xm, &ym, &loc2, &ctx,
                                         &glob, &l_g);
        assert_all_close(&p3.mean, &p4.mean, 1e-14, 1e-14);
    }

    /// The staged predict entry points are bitwise-identical to the
    /// unstaged ones: staging only reuses the support/global Cholesky
    /// factors the unstaged path would have rebuilt from the same
    /// inputs.
    #[test]
    fn staged_predicts_bitwise_match_unstaged() {
        let mut rng = Pcg64::seed(29);
        let d = 2;
        let (b, s, u) = (7, 4, 6);
        let hyp = SeArd::isotropic(d, 0.9, 1.2, 0.06);
        let xm = Mat::from_vec(b, d, rng.normals(b * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let ym = rng.normals(b);
        let be = NativeBackend;
        let loc = be.local_summary(&hyp, &xm, &ym, &xs);
        let ctx = SupportContext::new(&hyp, &xs);
        let glob = global_summary(&ctx, &[&loc]);
        let l_g = summaries::chol_global(&glob);

        let p1 = be.ppitc_predict(&hyp, &xu, &xs, &glob);
        let p2 = be.ppitc_predict_staged(&hyp, &xu, &ctx, &glob, &l_g);
        assert_eq!(p1.mean, p2.mean);
        assert_eq!(p1.var, p2.var);

        let q1 = be.ppic_predict(&hyp, &xu, &xs, &xm, &ym, &loc, &glob);
        let q2 = be.ppic_predict_staged(&hyp, &xu, &ctx, &xm, &ym, &loc,
                                        &glob, &l_g);
        assert_eq!(q1.mean, q2.mean);
        assert_eq!(q1.var, q2.var);
    }

    #[test]
    fn icf_global_pseudo_local_equivalence() {
        let mut rng = Pcg64::seed(22);
        let (r, u) = (4, 3);
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let f = Mat::from_vec(r, 6, rng.normals(r * 6));
        let phi = crate::linalg::matmul_nt(&f, &f);
        let sum_y = rng.normals(r);
        let sum_s = Mat::from_vec(r, u, rng.normals(r * u));
        let be = NativeBackend;
        let g = be.icf_global(&hyp, &sum_y, &sum_s, &phi);
        // Φ g.y == sum_y
        let mut phi_full = Mat::identity(r);
        let inv_sn2 = 1.0 / hyp.sn2();
        for i in 0..r {
            for j in 0..r {
                phi_full[(i, j)] += inv_sn2 * phi[(i, j)];
            }
        }
        let back = crate::linalg::matvec(&phi_full, &g.y);
        assert_all_close(&back, &sum_y, 1e-10, 1e-10);
    }
}
