//! PJRT execution of the AOT artifacts — the three-layer hot path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. One compiled executable per graph per
//! profile; inputs/outputs are f64 literals shaped by the manifest.
//!
//! **Feature gating:** the `xla` crate is not vendored in the offline
//! build image, so the real implementation compiles only with
//! `--features pjrt` (see Cargo.toml). Without the feature a stub
//! [`PjrtBackend`] is exported whose `load` returns a descriptive error;
//! every caller already handles a failed load (artifact-less test runs
//! skip, the CLI reports the error), so the default build is fully
//! functional on the native backend.
//!
//! Thread-safety (real impl): the `xla` wrapper types are raw-pointer
//! newtypes with no Send/Sync impls, but the underlying PJRT CPU client
//! is thread-safe for compilation and execution. We serialize all calls
//! behind one Mutex anyway (single host core — no parallelism to lose)
//! and assert Send+Sync on that basis.

pub use imp::PjrtBackend;

// Turn the otherwise-opaque "can't find crate for `xla`" error into
// instructions. Delete this guard as part of wiring the dependency —
// it exists only because `xla` cannot be declared (even optionally)
// without a reachable registry.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the `xla` crate, which is not vendored: \
     add `xla = \"...\"` (or a vendored path) under [dependencies] in \
     Cargo.toml and remove this compile_error! guard in \
     rust/src/runtime/pjrt.rs"
);

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use anyhow::{anyhow, bail, Result};

    use crate::gp::summaries::{
        GlobalSummary, IcfGlobalSummary, IcfLocalSummary, LocalSummary,
    };
    use crate::gp::Prediction;
    use crate::kernel::SeArd;
    use crate::linalg::Mat;
    use crate::runtime::artifacts::{
        ArtifactManifest, ProfileSpec, REQUIRED_GRAPHS,
    };
    use crate::runtime::backend::Backend;

    struct Engine {
        _client: xla::PjRtClient,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    // SAFETY: all access to the engine is serialized through `Mutex` in
    // `PjrtBackend`; the PJRT CPU plugin itself is thread-safe.
    unsafe impl Send for Engine {}

    /// Backend executing the manifest's graphs on the PJRT CPU client.
    pub struct PjrtBackend {
        pub profile: ProfileSpec,
        engine: Mutex<Engine>,
    }

    impl PjrtBackend {
        /// Compile every graph of `profile` from `manifest` (done once; the
        /// request path only executes).
        pub fn load(manifest: &ArtifactManifest, profile: &str) -> Result<PjrtBackend> {
            let spec = manifest.profile(profile)?.clone();
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
            let mut exes = HashMap::new();
            for gname in REQUIRED_GRAPHS {
                let path = manifest.graph_path(profile, gname)?;
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {gname}: {e:?}"))?;
                exes.insert(gname.to_string(), exe);
            }
            Ok(PjrtBackend {
                profile: spec,
                engine: Mutex::new(Engine { _client: client, exes }),
            })
        }

        /// Execute one graph; returns the decomposed output tuple.
        fn run(&self, graph: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
            let engine = self.engine.lock().unwrap();
            let exe = engine
                .exes
                .get(graph)
                .ok_or_else(|| anyhow!("graph {graph} not loaded"))?;
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute {graph}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal {graph}: {e:?}"))?;
            // aot.py lowers with return_tuple=True
            lit.to_tuple().map_err(|e| anyhow!("untuple {graph}: {e:?}"))
        }

        // ---- literal conversions -------------------------------------------

        fn lit_mat(m: &Mat) -> Result<xla::Literal> {
            xla::Literal::vec1(&m.data)
                .reshape(&[m.rows as i64, m.cols as i64])
                .map_err(|e| anyhow!("reshape literal: {e:?}"))
        }

        fn lit_vec(v: &[f64]) -> xla::Literal {
            xla::Literal::vec1(v)
        }

        fn mat_from(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
            let data = lit
                .to_vec::<f64>()
                .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
            if data.len() != rows * cols {
                bail!("literal size {} != {}x{}", data.len(), rows, cols);
            }
            Ok(Mat::from_vec(rows, cols, data))
        }

        fn vec_from(lit: &xla::Literal, n: usize) -> Result<Vec<f64>> {
            let data = lit
                .to_vec::<f64>()
                .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
            if data.len() != n {
                bail!("literal size {} != {}", data.len(), n);
            }
            Ok(data)
        }

        fn hyp_lit(&self, hyp: &SeArd) -> Result<xla::Literal> {
            let v = hyp.to_vec();
            if v.len() != self.profile.d + 2 {
                bail!("hyp dim {} != profile d+2 {}", v.len(), self.profile.d + 2);
            }
            Ok(Self::lit_vec(&v))
        }

        fn check(&self, what: &str, got: (usize, usize), want: (usize, usize)) -> Result<()> {
            if got != want {
                bail!(
                    "{what}: shape {}x{} != profile {}x{} — pad or re-AOT",
                    got.0, got.1, want.0, want.1
                );
            }
            Ok(())
        }
    }

    // SAFETY: see Engine — the Mutex serializes everything.
    unsafe impl Sync for PjrtBackend {}
    unsafe impl Send for PjrtBackend {}

    impl Backend for PjrtBackend {
        fn local_summary(&self, hyp: &SeArd, xm: &Mat, ym: &[f64], xs: &Mat)
            -> LocalSummary
        {
            let p = &self.profile;
            self.check("local_summary xm", (xm.rows, xm.cols), (p.block, p.d))
                .unwrap();
            self.check("local_summary xs", (xs.rows, xs.cols), (p.support, p.d))
                .unwrap();
            let out = self
                .run("local_summary", &[
                    Self::lit_mat(xm).unwrap(),
                    Self::lit_vec(ym),
                    Self::lit_mat(xs).unwrap(),
                    self.hyp_lit(hyp).unwrap(),
                ])
                .expect("pjrt local_summary");
            LocalSummary {
                y_dot: Self::vec_from(&out[0], p.support).unwrap(),
                s_dot: Self::mat_from(&out[1], p.support, p.support).unwrap(),
                l_m: Self::mat_from(&out[2], p.block, p.block).unwrap(),
            }
        }

        fn ppitc_predict(&self, hyp: &SeArd, xu: &Mat, xs: &Mat,
                         glob: &GlobalSummary) -> Prediction
        {
            let p = &self.profile;
            self.check("ppitc xu", (xu.rows, xu.cols), (p.pred_block, p.d))
                .unwrap();
            let out = self
                .run("ppitc_predict", &[
                    Self::lit_mat(xu).unwrap(),
                    Self::lit_mat(xs).unwrap(),
                    Self::lit_vec(&glob.y),
                    Self::lit_mat(&glob.s).unwrap(),
                    self.hyp_lit(hyp).unwrap(),
                ])
                .expect("pjrt ppitc_predict");
            Prediction {
                mean: Self::vec_from(&out[0], p.pred_block).unwrap(),
                var: Self::vec_from(&out[1], p.pred_block).unwrap(),
            }
        }

        fn ppic_predict(&self, hyp: &SeArd, xu: &Mat, xs: &Mat, xm: &Mat,
                        ym: &[f64], local: &LocalSummary, glob: &GlobalSummary)
                        -> Prediction
        {
            let p = &self.profile;
            self.check("ppic xu", (xu.rows, xu.cols), (p.pred_block, p.d)).unwrap();
            self.check("ppic xm", (xm.rows, xm.cols), (p.block, p.d)).unwrap();
            let out = self
                .run("ppic_predict", &[
                    Self::lit_mat(xu).unwrap(),
                    Self::lit_mat(xs).unwrap(),
                    Self::lit_mat(xm).unwrap(),
                    Self::lit_vec(ym),
                    Self::lit_mat(&local.l_m).unwrap(),
                    Self::lit_vec(&local.y_dot),
                    Self::lit_mat(&local.s_dot).unwrap(),
                    Self::lit_vec(&glob.y),
                    Self::lit_mat(&glob.s).unwrap(),
                    self.hyp_lit(hyp).unwrap(),
                ])
                .expect("pjrt ppic_predict");
            Prediction {
                mean: Self::vec_from(&out[0], p.pred_block).unwrap(),
                var: Self::vec_from(&out[1], p.pred_block).unwrap(),
            }
        }

        fn icf_local(&self, hyp: &SeArd, xm: &Mat, ym: &[f64], xu: &Mat,
                     f_m: &Mat) -> IcfLocalSummary
        {
            let p = &self.profile;
            self.check("icf_local f_m", (f_m.rows, f_m.cols), (p.rank, p.block))
                .unwrap();
            let out = self
                .run("icf_local", &[
                    Self::lit_mat(xm).unwrap(),
                    Self::lit_vec(ym),
                    Self::lit_mat(xu).unwrap(),
                    Self::lit_mat(f_m).unwrap(),
                    self.hyp_lit(hyp).unwrap(),
                ])
                .expect("pjrt icf_local");
            IcfLocalSummary {
                y_dot: Self::vec_from(&out[0], p.rank).unwrap(),
                s_dot: Self::mat_from(&out[1], p.rank, p.pred_block).unwrap(),
                phi: Self::mat_from(&out[2], p.rank, p.rank).unwrap(),
            }
        }

        fn icf_global(&self, hyp: &SeArd, sum_y: &[f64], sum_s: &Mat,
                      sum_phi: &Mat) -> IcfGlobalSummary
        {
            let p = &self.profile;
            let out = self
                .run("icf_global", &[
                    Self::lit_vec(sum_y),
                    Self::lit_mat(sum_s).unwrap(),
                    Self::lit_mat(sum_phi).unwrap(),
                    self.hyp_lit(hyp).unwrap(),
                ])
                .expect("pjrt icf_global");
            IcfGlobalSummary {
                y: Self::vec_from(&out[0], p.rank).unwrap(),
                s: Self::mat_from(&out[1], p.rank, p.pred_block).unwrap(),
            }
        }

        fn icf_predict(&self, hyp: &SeArd, xu: &Mat, xm: &Mat, ym: &[f64],
                       s_dot_m: &Mat, glob: &IcfGlobalSummary) -> Prediction
        {
            let p = &self.profile;
            let out = self
                .run("icf_predict", &[
                    Self::lit_mat(xu).unwrap(),
                    Self::lit_mat(xm).unwrap(),
                    Self::lit_vec(ym),
                    Self::lit_mat(s_dot_m).unwrap(),
                    Self::lit_vec(&glob.y),
                    Self::lit_mat(&glob.s).unwrap(),
                    self.hyp_lit(hyp).unwrap(),
                ])
                .expect("pjrt icf_predict");
            Prediction {
                mean: Self::vec_from(&out[0], p.pred_block).unwrap(),
                var: Self::vec_from(&out[1], p.pred_block).unwrap(),
            }
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use anyhow::{bail, Result};

    use crate::gp::summaries::{
        GlobalSummary, IcfGlobalSummary, IcfLocalSummary, LocalSummary,
    };
    use crate::gp::Prediction;
    use crate::kernel::SeArd;
    use crate::linalg::Mat;
    use crate::runtime::artifacts::{ArtifactManifest, ProfileSpec};
    use crate::runtime::backend::Backend;

    /// Stub exported when the crate is built without `--features pjrt`.
    /// `load` always fails, so the `Backend` methods are unreachable.
    pub struct PjrtBackend {
        pub profile: ProfileSpec,
    }

    impl PjrtBackend {
        pub fn load(_manifest: &ArtifactManifest, _profile: &str)
            -> Result<PjrtBackend>
        {
            bail!(
                "pgpr was built without the `pjrt` feature; rebuild with \
                 `cargo build --features pjrt` (requires the `xla` crate — \
                 see Cargo.toml) or use `--backend native`"
            );
        }
    }

    impl Backend for PjrtBackend {
        fn local_summary(&self, _: &SeArd, _: &Mat, _: &[f64], _: &Mat)
            -> LocalSummary
        {
            unreachable!("pjrt stub cannot be constructed");
        }

        fn ppitc_predict(&self, _: &SeArd, _: &Mat, _: &Mat, _: &GlobalSummary)
            -> Prediction
        {
            unreachable!("pjrt stub cannot be constructed");
        }

        fn ppic_predict(&self, _: &SeArd, _: &Mat, _: &Mat, _: &Mat, _: &[f64],
                        _: &LocalSummary, _: &GlobalSummary) -> Prediction
        {
            unreachable!("pjrt stub cannot be constructed");
        }

        fn icf_local(&self, _: &SeArd, _: &Mat, _: &[f64], _: &Mat, _: &Mat)
            -> IcfLocalSummary
        {
            unreachable!("pjrt stub cannot be constructed");
        }

        fn icf_global(&self, _: &SeArd, _: &[f64], _: &Mat, _: &Mat)
            -> IcfGlobalSummary
        {
            unreachable!("pjrt stub cannot be constructed");
        }

        fn icf_predict(&self, _: &SeArd, _: &Mat, _: &Mat, _: &[f64], _: &Mat,
                       _: &IcfGlobalSummary) -> Prediction
        {
            unreachable!("pjrt stub cannot be constructed");
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}
