//! Execution runtime: the [`Backend`] abstraction over the six
//! block-level graph operations, with a native-rust implementation and a
//! PJRT implementation that loads the AOT HLO-text artifacts built by
//! `python/compile/aot.py` (the three-layer hot path).
//!
//! # The Backend / AOT split
//!
//! Every protocol step that touches numbers goes through the six
//! [`Backend`] methods (Definitions 2–8 of the paper: local summary, the
//! two block predictions, and the three ICF ops). That boundary is what
//! lets one coordinator codebase run two ways:
//!
//! * [`NativeBackend`] — pure rust via `gp::summaries`, any shapes; the
//!   numerical reference and what sweeps/tests use.
//! * [`PjrtBackend`] — executes HLO-text artifacts that
//!   `python/compile/aot.py` lowered ahead of time from the same math
//!   written in JAX (with the Pallas SE-Gram kernel inside); shapes are
//!   pinned by the [`ArtifactManifest`]. This is the serving hot path:
//!   python exists only at build time, the request path is rust + PJRT.
//!
//! Because `Backend` is `Send + Sync` and every call is stateless, the
//! same backend instance is shared freely across the simulated
//! machines, including under the thread-parallel
//! [`crate::cluster::ParallelExecutor`].
//!
//! `PjrtBackend` needs the `xla` crate and is gated behind the `pjrt`
//! cargo feature (not vendored offline — see Cargo.toml); without it a
//! stub whose `load` errors cleanly is exported, and integration tests
//! that need artifacts skip themselves.

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, GraphSpec, ProfileSpec};
pub use backend::{Backend, NativeBackend};
pub use pjrt::PjrtBackend;
