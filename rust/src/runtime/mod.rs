//! Execution runtime: the [`Backend`] abstraction over the six
//! block-level graph operations, with a native-rust implementation and a
//! PJRT implementation that loads the AOT HLO-text artifacts built by
//! `python/compile/aot.py` (the three-layer hot path).

pub mod artifacts;
pub mod backend;
pub mod pjrt;

pub use artifacts::{ArtifactManifest, GraphSpec, ProfileSpec};
pub use backend::{Backend, NativeBackend};
pub use pjrt::PjrtBackend;
