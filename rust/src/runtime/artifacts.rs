//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the rust runtime. The runtime only ever loads artifacts through
//! this manifest — shapes, input order and output arity are all pinned
//! here at build time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One lowered graph: file name, ordered input shapes, output arity.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    pub file: String,
    /// (name, shape) in call order; scalars are rank-1 [n] vectors here
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: usize,
    pub sha256: String,
}

/// One shape profile (d, B, S, U, R) with its six graphs.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileSpec {
    pub name: String,
    pub d: usize,
    pub block: usize,
    pub support: usize,
    pub pred_block: usize,
    pub rank: usize,
    pub graphs: BTreeMap<String, GraphSpec>,
}

/// The parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub profiles: BTreeMap<String, ProfileSpec>,
}

/// The graph names every profile must provide.
pub const REQUIRED_GRAPHS: [&str; 6] = [
    "local_summary",
    "ppitc_predict",
    "ppic_predict",
    "icf_local",
    "icf_global",
    "icf_predict",
];

impl ArtifactManifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactManifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        if root.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest format is not hlo-text");
        }
        if root.get("dtype").and_then(Json::as_str) != Some("float64") {
            bail!("manifest dtype is not float64");
        }

        let mut profiles = BTreeMap::new();
        let profs = root
            .get("profiles")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing profiles"))?;
        for (pname, p) in profs {
            let field = |k: &str| -> Result<usize> {
                p.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("profile {pname}: missing {k}"))
            };
            let mut graphs = BTreeMap::new();
            let gobj = p
                .get("graphs")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("profile {pname}: missing graphs"))?;
            for (gname, g) in gobj {
                let file = g
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{pname}/{gname}: missing file"))?
                    .to_string();
                let inputs = g
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("{pname}/{gname}: missing inputs"))?
                    .iter()
                    .map(|i| -> Result<(String, Vec<usize>)> {
                        let triple = i
                            .as_arr()
                            .ok_or_else(|| anyhow!("bad input entry"))?;
                        let name = triple[0]
                            .as_str()
                            .ok_or_else(|| anyhow!("bad input name"))?
                            .to_string();
                        let shape = triple[1]
                            .as_arr()
                            .ok_or_else(|| anyhow!("bad input shape"))?
                            .iter()
                            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                            .collect::<Result<Vec<usize>>>()?;
                        Ok((name, shape))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let outputs = g
                    .get("outputs")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{pname}/{gname}: missing outputs"))?;
                let sha256 = g
                    .get("sha256")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                graphs.insert(
                    gname.clone(),
                    GraphSpec { file, inputs, outputs, sha256 },
                );
            }
            for req in REQUIRED_GRAPHS {
                if !graphs.contains_key(req) {
                    bail!("profile {pname}: missing graph {req}");
                }
            }
            profiles.insert(
                pname.clone(),
                ProfileSpec {
                    name: pname.clone(),
                    d: field("d")?,
                    block: field("block")?,
                    support: field("support")?,
                    pred_block: field("pred_block")?,
                    rank: field("rank")?,
                    graphs,
                },
            );
        }
        Ok(ArtifactManifest { dir, profiles })
    }

    pub fn profile(&self, name: &str) -> Result<&ProfileSpec> {
        self.profiles
            .get(name)
            .ok_or_else(|| anyhow!("unknown profile {name} (have: {:?})",
                                   self.profiles.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of a graph's HLO text file.
    pub fn graph_path(&self, profile: &str, graph: &str) -> Result<PathBuf> {
        let p = self.profile(profile)?;
        let g = p
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow!("profile {profile}: no graph {graph}"))?;
        Ok(self.dir.join(&g.file))
    }
}

/// Default artifacts directory: `$PGPR_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("PGPR_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<ArtifactManifest> {
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            Some(ArtifactManifest::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest_when_present() {
        // `make artifacts` must run before `cargo test` (the Makefile
        // enforces this); skip quietly if absent (e.g. docs-only builds).
        let Some(m) = repo_artifacts() else { return };
        let tiny = m.profile("tiny").unwrap();
        assert_eq!(tiny.d, 3);
        assert_eq!(tiny.graphs.len(), 6);
        for g in REQUIRED_GRAPHS {
            let path = m.graph_path("tiny", g).unwrap();
            assert!(path.exists(), "{path:?}");
        }
        // input shape sanity for local_summary: (B,d), (B,), (S,d), (d+2,)
        let ls = &tiny.graphs["local_summary"];
        assert_eq!(ls.inputs[0].1, vec![tiny.block, tiny.d]);
        assert_eq!(ls.inputs[1].1, vec![tiny.block]);
        assert_eq!(ls.inputs[2].1, vec![tiny.support, tiny.d]);
        assert_eq!(ls.inputs[3].1, vec![tiny.d + 2]);
        assert_eq!(ls.outputs, 3);
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("pgpr_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"),
                       r#"{"format": "protobuf", "dtype": "float64",
                           "profiles": {}}"#).unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"),
                       r#"{"format": "hlo-text", "dtype": "float64",
                           "profiles": {"p": {"d": 1, "block": 2,
                           "support": 3, "pred_block": 4, "rank": 5,
                           "graphs": {}}}}"#).unwrap();
        let err = ArtifactManifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("missing graph"));
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactManifest::load("/nonexistent/really").is_err());
    }
}
