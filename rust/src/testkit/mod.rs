//! Testing substrates: a property-based testing mini-framework and
//! numeric assertion helpers (no proptest in the offline vendor set).

pub mod prop;

/// Assert two floats are close: |a-b| <= atol + rtol*|b|.
#[track_caller]
pub fn assert_close(a: f64, b: f64, rtol: f64, atol: f64) {
    let tol = atol + rtol * b.abs();
    assert!(
        (a - b).abs() <= tol,
        "assert_close failed: a={a:.12e} b={b:.12e} |diff|={:.3e} tol={tol:.3e}",
        (a - b).abs()
    );
}

/// Assert two slices are elementwise close.
#[track_caller]
pub fn assert_all_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "assert_all_close failed at [{i}]: a={x:.12e} b={y:.12e} \
             |diff|={:.3e} tol={tol:.3e}",
            (x - y).abs()
        );
    }
}

/// Max absolute elementwise deviation.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_passes() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, 0.0);
        assert_all_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-13], 1e-9, 0.0);
    }

    #[test]
    #[should_panic]
    fn close_fails() {
        assert_close(1.0, 1.1, 1e-9, 1e-9);
    }

    #[test]
    fn max_diff() {
        assert_eq!(max_abs_diff(&[0.0, 1.0], &[0.5, 1.0]), 0.5);
    }
}
