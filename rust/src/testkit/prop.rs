//! Property-based testing mini-framework.
//!
//! The offline vendor set has no proptest/quickcheck, so this module
//! provides the 20% that covers our needs: seeded generators built on
//! [`crate::util::Pcg64`], a `prop_check` runner that executes a property
//! over many random cases and reports the failing seed, and common
//! generator combinators for the numeric domains in this repo.
//!
//! Usage (`no_run`: doctest executables can't resolve the xla rpath):
//! ```no_run
//! use pgpr::testkit::prop::{prop_check, Gen};
//! prop_check("addition commutes", 64, |g| {
//!     let a = g.f64_in(-10.0, 10.0);
//!     let b = g.f64_in(-10.0, 10.0);
//!     assert!((a + b - (b + a)).abs() < 1e-15);
//! });
//! ```

use crate::cluster::FaultPlan;
use crate::util::Pcg64;

/// Per-case generator handle passed to properties.
pub struct Gen {
    rng: Pcg64,
    pub case: usize,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "usize_in({lo},{hi})");
        lo + self.rng.below(hi - lo)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        self.rng.normals(n)
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    /// Random deterministic [`FaultPlan`] over `machines` machines and
    /// the given protocol `phases`: optional drops (bounded retries),
    /// optional stragglers, always a finite timeout/backoff, and each
    /// machine independently scheduled to die at a random phase with
    /// probability 1/5. The chaos property suite feeds these to every
    /// protocol and asserts completion-or-typed-error.
    pub fn fault_plan(&mut self, machines: usize, phases: &[&str])
                      -> FaultPlan {
        let mut plan = FaultPlan::seeded(self.rng.next_u64());
        if self.bool() {
            plan = plan.with_drops(self.f64_in(0.0, 0.35),
                                   self.usize_in(1, 6));
        }
        if self.bool() {
            plan = plan.with_stragglers(self.f64_in(0.0, 0.6),
                                        self.f64_in(1e-5, 5e-3));
        }
        plan = plan.with_timeout(self.f64_in(1e-5, 1e-3),
                                 self.f64_in(1.0, 3.0));
        for m in 0..machines {
            if self.usize_in(0, 5) == 0 {
                let phase = *self.choose(phases);
                plan = plan.kill(m, phase);
            }
        }
        plan
    }
}

/// Run `f` on a worker thread and panic if it does not finish within
/// `timeout` — turns a deadlocked or livelocked property into a test
/// failure instead of a hung suite. Panics from `f` are re-raised on
/// the caller's thread; on timeout the worker thread is leaked (fine
/// for a failing test process).
pub fn with_watchdog<T, F>(timeout: std::time::Duration, f: F) -> T
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    use std::sync::mpsc;
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let out = f();
        // receiver hung up only on timeout; nothing to do then
        let _ = tx.send(());
        out
    });
    match rx.recv_timeout(timeout) {
        // Ok: worker signalled completion. Disconnected: worker
        // panicked before signalling (sender dropped) — join returns
        // the payload to re-raise either way.
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => {
            match handle.join() {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("watchdog: work did not finish within {timeout:?}")
        }
    }
}

/// Run `property` over `cases` random cases. On panic, re-raises with the
/// case index and derived seed in the message so the failure replays with
/// `replay_case`.
pub fn prop_check(name: &str, cases: usize, mut property: impl FnMut(&mut Gen)) {
    for case in 0..cases {
        let seed = derive_seed(name, case);
        let mut g = Gen { rng: Pcg64::seed(seed), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case of a property by index.
pub fn replay_case(name: &str, case: usize, mut property: impl FnMut(&mut Gen)) {
    let seed = derive_seed(name, case);
    let mut g = Gen { rng: Pcg64::seed(seed), case };
    property(&mut g);
}

fn derive_seed(name: &str, case: usize) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check("trivial", 32, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_failing_case() {
        let r = std::panic::catch_unwind(|| {
            prop_check("always-fails", 4, |_| panic!("boom"));
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("case 0"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn deterministic_per_case() {
        let mut first = Vec::new();
        prop_check("det", 8, |g| {
            first.push(g.f64_in(0.0, 1.0));
        });
        let mut second = Vec::new();
        prop_check("det", 8, |g| {
            second.push(g.f64_in(0.0, 1.0));
        });
        assert_eq!(first, second);
    }

    #[test]
    fn replay_matches_run() {
        let mut from_run = None;
        prop_check("replay", 3, |g| {
            if g.case == 2 {
                from_run = Some(g.f64_in(0.0, 1.0));
            }
        });
        let mut from_replay = None;
        replay_case("replay", 2, |g| {
            from_replay = Some(g.f64_in(0.0, 1.0));
        });
        assert_eq!(from_run, from_replay);
    }

    /// The fault-plan generator is deterministic per seed and every
    /// sampled knob stays inside its documented range.
    #[test]
    fn fault_plan_generator_is_deterministic_and_bounded() {
        let phases = ["alpha", "beta", "gamma"];
        let mut a = Gen { rng: Pcg64::seed(7), case: 0 };
        let mut b = Gen { rng: Pcg64::seed(7), case: 0 };
        for _ in 0..32 {
            let pa = a.fault_plan(4, &phases);
            let pb = b.fault_plan(4, &phases);
            assert_eq!(pa, pb, "same seed must give the same plan");
            assert!((0.0..=0.35).contains(&pa.drop_prob));
            assert!(pa.max_retries >= 1 && pa.max_retries < 6);
            assert!((0.0..=0.6).contains(&pa.straggler_prob));
            assert!(pa.straggler_delay_s < 5e-3);
            assert!((1e-5..1e-3).contains(&pa.timeout_s));
            assert!((1.0..3.0).contains(&pa.backoff));
            for (m, ph) in &pa.deaths {
                assert!(*m < 4, "death machine {m} out of range");
                assert!(phases.contains(&ph.as_str()), "phase {ph}");
            }
        }
    }

    #[test]
    fn watchdog_passes_through_results_and_panics() {
        let v = with_watchdog(std::time::Duration::from_secs(5), || 42);
        assert_eq!(v, 42);
        let r = std::panic::catch_unwind(|| {
            with_watchdog(std::time::Duration::from_secs(5), || {
                panic!("inner boom")
            })
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap();
        assert!(msg.contains("inner boom"), "{msg}");
    }

    #[test]
    fn watchdog_times_out_hung_work() {
        let r = std::panic::catch_unwind(|| {
            with_watchdog(std::time::Duration::from_millis(50), || {
                std::thread::sleep(std::time::Duration::from_secs(600));
            })
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("watchdog"), "{msg}");
    }

    #[test]
    fn generators_cover_ranges() {
        prop_check("ranges", 64, |g| {
            let u = g.usize_in(3, 9);
            assert!((3..9).contains(&u));
            let v = g.uniform_vec(5, -1.0, 1.0);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }
}
