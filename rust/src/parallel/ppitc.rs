//! pPITC — Section 3, Steps 1–4, over the simulated cluster.
//!
//! Step 1 (distribute data) is assumed done (Table 1 assumption (c): the
//! data is already distributed); Step 2 computes local summaries on every
//! machine; Step 3 reduces them to the master and broadcasts the global
//! summary back; Step 4 distributes predictions. A final `collect` phase
//! gathers predictions to the master for reporting — it is *outside* the
//! paper's protocol, so it is recorded as a separate phase.

use super::{
    f64_bytes, rebalance_dead, reroute_queries_round_robin, ClusterSpec,
    FaultRun, ProtocolOutput,
};
use crate::cluster::mpi::MASTER;
use crate::cluster::MachinesLost;
use crate::gp::summaries::{LocalSummary, SupportContext};
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::runtime::Backend;

/// Run the pPITC protocol.
///
/// * `d_blocks[m]` / `u_blocks[m]` — machine m's training/test rows
///   (Definition 1 partitions; use `data::partition`).
/// * predictions are returned in the original row order of `xu`.
pub fn run(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    u_blocks: &[Vec<usize>],
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> ProtocolOutput {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m, "d_blocks vs machines");
    assert_eq!(u_blocks.len(), m, "u_blocks vs machines");
    let s = xs.rows;
    let _obsv_span = crate::obsv::span("protocol.pPITC")
        .with_u64("machines", m as u64)
        .with_u64("support", s as u64);
    let mut cluster = spec.cluster();
    // Master-side block math shares the executor's pool (degrades to
    // serial inside node closures / under a serial executor).
    let lctx = spec.exec.linalg_ctx();

    // prior mean: empirical train mean (known to all machines — each can
    // compute its block sum; we charge the master the negligible combine)
    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;

    // STEP 2: local summaries, one per machine.
    let locals = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.local_summary(hyp, &xm, &ym, xs)
    });
    cluster.phase("local_summary");

    // STEP 3: reduce local summaries to master, assimilate, broadcast.
    // The support context and chol(Σ̈_SS) are staged here once: every
    // machine already holds Σ_SS and the broadcast global summary, so
    // the hoist adds no traffic — it only stops Step 4 from
    // re-factorizing two |S|×|S| matrices per machine.
    cluster.reduce_to_master(f64_bytes(s * s + s));
    let (sctx, global, l_g) = cluster.compute_on(MASTER, || {
        let ctx = SupportContext::new_ctx(&lctx, hyp, xs);
        let refs: Vec<_> = locals.iter().collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let l_g = crate::gp::summaries::chol_global_ctx(&lctx, &global);
        (ctx, global, l_g)
    });
    cluster.bcast_from_master(f64_bytes(s * s + s));
    cluster.phase("global_summary");

    // STEP 4: distributed predictions.
    let preds: Vec<Prediction> = cluster.compute_all(|mid| {
        let xu_m = xu.select_rows(&u_blocks[mid]);
        let mut p = backend.ppitc_predict_staged(hyp, &xu_m, &sctx,
                                                 &global, &l_g);
        p.shift_mean(y_mean);
        p
    });
    cluster.phase("predict");

    // collect (reporting only; not part of the paper's incurred time)
    let max_u = u_blocks.iter().map(Vec::len).max().unwrap_or(0);
    cluster.gather_to_master(f64_bytes(2 * max_u));
    cluster.phase("collect");

    ProtocolOutput {
        prediction: Prediction::scatter(&preds, u_blocks, xu.rows),
        metrics: cluster.finish(),
    }
}

/// Fault-aware pPITC: the same Step 1–4 protocol as [`run`], mediated
/// by `spec`'s fault transport. On machine death the master rebalances
/// the dead machine's data rows round-robin onto survivors, adopters
/// recompute their (enlarged) local summaries before the global
/// summary is sealed, and query rows re-route round-robin; after the
/// seal, deaths only move ownership — pPITC predictions depend solely
/// on the sealed global summary, so they stay well-defined. With a
/// zero plan the result is bitwise-identical to [`run`]. Errs only
/// when every machine is lost.
#[allow(clippy::too_many_arguments)]
pub fn try_run(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    u_blocks: &[Vec<usize>],
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> Result<FaultRun, MachinesLost> {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m, "d_blocks vs machines");
    assert_eq!(u_blocks.len(), m, "u_blocks vs machines");
    let s = xs.rows;
    let _obsv_span = crate::obsv::span("protocol.pPITC")
        .with_u64("machines", m as u64)
        .with_u64("support", s as u64);
    let mut cluster = spec.cluster();
    let lctx = spec.exec.linalg_ctx();
    // rebalance payload: one data row is d coords + 1 target
    let d_row_bytes = f64_bytes(xd.cols + 1);
    let u_row_bytes = f64_bytes(xu.cols);
    let mut db: Vec<Vec<usize>> = d_blocks.to_vec();
    let mut ub: Vec<Vec<usize>> = u_blocks.to_vec();

    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
    let local_of = |rows: &[usize]| {
        let xm = xd.select_rows(rows);
        let ym: Vec<f64> = rows.iter().map(|&i| y[i] - y_mean).collect();
        backend.local_summary(hyp, &xm, &ym, xs)
    };

    // Deaths discovered on entering Step 2: rebalance before anyone
    // computes, so adopters summarize their enlarged blocks directly.
    let dead = cluster.take_deaths("local_summary");
    rebalance_dead(&mut cluster, &dead, &mut db, d_row_bytes,
                   "local_summary")?;
    reroute_queries_round_robin(&mut cluster, &dead, &mut ub, u_row_bytes);

    // STEP 2: local summaries on the alive machines.
    let mut locals: Vec<Option<LocalSummary>> =
        cluster.compute_alive(|mid| local_of(&db[mid]));
    cluster.phase("local_summary");

    // Deaths discovered on entering Step 3: adopters recompute their
    // local summaries so the global summary still covers every row.
    let dead = cluster.take_deaths("global_summary");
    for &dm in &dead {
        locals[dm] = None;
    }
    let adopters = rebalance_dead(&mut cluster, &dead, &mut db,
                                  d_row_bytes, "global_summary")?;
    reroute_queries_round_robin(&mut cluster, &dead, &mut ub, u_row_bytes);
    for &a in &adopters {
        locals[a] = Some(cluster.compute_on(a, || local_of(&db[a])));
    }

    // STEP 3: reduce with bounded retry. A retry-exhausted sender is
    // declared dead; its block rebalances, adopters recompute, and the
    // reduce re-issues over the survivors. Every round kills at least
    // one machine, so the loop is bounded by M.
    loop {
        let failed = cluster.reduce_to_master(f64_bytes(s * s + s));
        if failed.is_empty() {
            break;
        }
        for &dm in &failed {
            locals[dm] = None;
        }
        let adopters = rebalance_dead(&mut cluster, &failed, &mut db,
                                      d_row_bytes, "global_summary")?;
        reroute_queries_round_robin(&mut cluster, &failed, &mut ub,
                                    u_row_bytes);
        for &a in &adopters {
            locals[a] = Some(cluster.compute_on(a, || local_of(&db[a])));
        }
    }
    let root = cluster.master();
    let (sctx, global, l_g) = cluster.compute_on(root, || {
        let ctx = SupportContext::new_ctx(&lctx, hyp, xs);
        let refs: Vec<&LocalSummary> =
            locals.iter().filter_map(|o| o.as_ref()).collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let l_g = crate::gp::summaries::chol_global_ctx(&lctx, &global);
        (ctx, global, l_g)
    });
    // The global summary is sealed: a receiver dying during the bcast
    // only hands its blocks on (no recompute — predictions no longer
    // depend on the partition).
    let failed = cluster.bcast_from_master(f64_bytes(s * s + s));
    if !failed.is_empty() {
        for &dm in &failed {
            locals[dm] = None;
        }
        rebalance_dead(&mut cluster, &failed, &mut db, d_row_bytes,
                       "global_summary")?;
        reroute_queries_round_robin(&mut cluster, &failed, &mut ub,
                                    u_row_bytes);
    }
    cluster.phase("global_summary");

    // Deaths on entering Step 4: ownership + query re-route only.
    let dead = cluster.take_deaths("predict");
    rebalance_dead(&mut cluster, &dead, &mut db, d_row_bytes, "predict")?;
    reroute_queries_round_robin(&mut cluster, &dead, &mut ub, u_row_bytes);

    // STEP 4: distributed predictions on the alive machines.
    let preds = cluster.compute_alive(|mid| {
        let xu_m = xu.select_rows(&ub[mid]);
        let mut p = backend.ppitc_predict_staged(hyp, &xu_m, &sctx,
                                                 &global, &l_g);
        p.shift_mean(y_mean);
        p
    });
    cluster.phase("predict");

    // collect (reporting only): a machine dying mid-gather had already
    // computed its predictions; the retry round that detected the loss
    // re-gathers them from the master's partial buffer, so no output is
    // lost — the dead machine's data rows still hand over to survivors
    // for the coverage audit.
    let max_u = ub.iter().map(Vec::len).max().unwrap_or(0);
    loop {
        let failed = cluster.gather_to_master(f64_bytes(2 * max_u));
        if failed.is_empty() {
            break;
        }
        rebalance_dead(&mut cluster, &failed, &mut db, d_row_bytes,
                       "collect")?;
    }
    cluster.phase("collect");

    let survivors = cluster.alive_ids();
    let preds: Vec<Prediction> = preds
        .into_iter()
        .map(|p| p.unwrap_or_else(Prediction::empty))
        .collect();
    Ok(FaultRun {
        output: ProtocolOutput {
            prediction: Prediction::scatter(&preds, &ub, xu.rows),
            metrics: cluster.finish(),
        },
        d_blocks: db,
        u_blocks: ub,
        survivors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::NetworkModel;
    use crate::data::partition::random_partition;
    use crate::gp::pitc::PitcGp;
    use crate::runtime::NativeBackend;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// THEOREM 1, protocol side: the full distributed run (partitioned
    /// predictions included) equals centralized PITC on the same blocks.
    #[test]
    fn theorem1_ppitc_equals_centralized_pitc() {
        prop_check("thm1-protocol", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 5);
            let n = m * g.usize_in(2, 5);
            let u = m * g.usize_in(1, 3);
            let s = g.usize_in(2, 5);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());
            let u_blocks = random_partition(u, m, g.rng());

            let out = run(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks,
                          &NativeBackend, &ClusterSpec::new(m));
            let centralized = PitcGp::fit(&hyp, &xd, &y, &xs, &d_blocks);
            let want = centralized.predict(&xu);
            assert_all_close(&out.prediction.mean, &want.mean, 1e-9, 1e-9);
            assert_all_close(&out.prediction.var, &want.var, 1e-9, 1e-9);
        });
    }

    /// Protocol metrics: phases in order, traffic matches the O(|S|² log M)
    /// communication complexity of Table 1.
    #[test]
    fn metrics_shape() {
        let mut g_rng = crate::util::Pcg64::seed(3);
        let (n, u, s, m, d) = (12, 4, 3, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, g_rng.normals(n * d));
        let xs = Mat::from_vec(s, d, g_rng.normals(s * d));
        let xu = Mat::from_vec(u, d, g_rng.normals(u * d));
        let y = g_rng.normals(n);
        let d_blocks = random_partition(n, m, &mut g_rng);
        let u_blocks = random_partition(u, m, &mut g_rng);
        let out = run(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks,
                      &NativeBackend, &ClusterSpec::new(m));
        let names: Vec<&str> =
            out.metrics.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names,
                   vec!["local_summary", "global_summary", "predict", "collect"]);
        // reduce + bcast of (s²+s) doubles across m-1 senders, plus the
        // collect gather of 2·u/m values
        let summary_bytes = 8 * (s * s + s) * (m - 1) * 2;
        let collect_bytes = 8 * 2 * (u / m) * (m - 1);
        assert_eq!(out.metrics.bytes_sent, summary_bytes + collect_bytes);
        assert!(out.metrics.makespan > 0.0);
        assert!(out.metrics.max_compute <= out.metrics.total_compute);
    }

    /// Executing machines on a real thread pool must not change a single
    /// bit of the output (the Theorem 1 oracle applied to the executor).
    #[test]
    fn thread_parallel_matches_serial() {
        let mut rng = crate::util::Pcg64::seed(8);
        let (n, u, s, m, d) = (40, 12, 5, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let u_blocks = random_partition(u, m, &mut rng);
        let serial = run(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks,
                         &NativeBackend, &ClusterSpec::new(m));
        let par = run(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks,
                      &NativeBackend, &ClusterSpec::with_threads(m, 4));
        assert_eq!(serial.prediction.mean, par.prediction.mean);
        assert_eq!(serial.prediction.var, par.prediction.var);
        assert_eq!(par.metrics.threads, 4);
        assert!(par.metrics.wall_s > 0.0);
        // same traffic model regardless of executor
        assert_eq!(serial.metrics.bytes_sent, par.metrics.bytes_sent);
        assert_eq!(serial.metrics.messages, par.metrics.messages);
    }

    /// The simulated makespan must beat the serial sum of compute when
    /// M > 1 (that is the whole point of the protocol).
    #[test]
    fn parallelism_visible_in_makespan() {
        let mut rng = crate::util::Pcg64::seed(5);
        let (n, u, s, m, d) = (60, 10, 6, 5, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let u_blocks = random_partition(u, m, &mut rng);
        let out = run(&hyp, &xd, &y, &xs, &xu, &d_blocks, &u_blocks,
                      &NativeBackend,
                      &ClusterSpec {
                          machines: m,
                          net: NetworkModel::instant(),
                          exec: crate::cluster::ParallelExecutor::serial(),
                          faults: None,
                      });
        assert!(out.metrics.makespan < out.metrics.total_compute,
                "makespan {} !< total {}", out.metrics.makespan,
                out.metrics.total_compute);
    }
}
