//! The paper's contribution: parallel GP regression protocols over the
//! simulated cluster — pPITC (Section 3), pPIC (Definition 5), and
//! pICF-based GP (Section 4) — plus online/incremental assimilation
//! (§5.2).
//!
//! Every protocol follows the paper's step structure exactly; block-level
//! math is dispatched through a [`crate::runtime::Backend`] so the same
//! coordinator code runs on the native backend (sweeps) or the PJRT
//! artifacts (serving hot path). Equivalence to the centralized
//! counterparts (Theorems 1–3) is asserted by property tests.

pub mod online;
pub mod picf;
pub mod ppic;
pub mod ppitc;

use crate::cluster::{NetworkModel, RunMetrics};
use crate::gp::Prediction;

/// Cluster configuration for a protocol run.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub machines: usize,
    pub net: NetworkModel,
}

impl ClusterSpec {
    pub fn new(machines: usize) -> ClusterSpec {
        ClusterSpec { machines, net: NetworkModel::gigabit() }
    }
}

/// Result of a protocol run: the predictive distribution (in original
/// test-row order) plus the simulated-run metrics.
#[derive(Debug, Clone)]
pub struct ProtocolOutput {
    pub prediction: Prediction,
    pub metrics: RunMetrics,
}

/// Bytes of a f64 payload of `n` elements.
pub(crate) fn f64_bytes(n: usize) -> usize {
    n * std::mem::size_of::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_spec_default_net() {
        let s = ClusterSpec::new(8);
        assert_eq!(s.machines, 8);
        assert_eq!(s.net, NetworkModel::gigabit());
    }

    #[test]
    fn f64_bytes_counts() {
        assert_eq!(f64_bytes(3), 24);
    }
}
