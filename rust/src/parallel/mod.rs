//! The paper's contribution: parallel GP regression protocols over the
//! simulated cluster — pPITC (Section 3), pPIC (Definition 5), and
//! pICF-based GP (Section 4) — plus online/incremental assimilation
//! (§5.2).
//!
//! # Equivalence guarantees (Theorems 1–3)
//!
//! The distributed protocols are *exact reformulations* of their
//! centralized counterparts, not new approximations:
//!
//! * **Theorem 1** — pPITC run on M machines produces the same
//!   predictive mean and variance as centralized PITC
//!   ([`crate::gp::pitc::PitcGp`]) on the same partition.
//! * **Theorem 2** — pPIC likewise equals centralized PIC
//!   ([`crate::gp::pic::PicGp`]), and with M = 1 collapses to the exact
//!   full GP.
//! * **Theorem 3 (§4)** — the pICF-based GP equals the centralized
//!   ICF-based GP ([`crate::gp::icf_gp::IcfGp`]) at the same rank; the
//!   row-based parallel ICF even reproduces the serial factor pivot for
//!   pivot.
//!
//! These identities double as the correctness oracle for *how* the work
//! is executed: whether the simulated machines run one after another or
//! truly concurrently on a [`crate::cluster::ParallelExecutor`] thread
//! pool (set [`ClusterSpec::with_threads`]), predictions must match to
//! ≤1e-10 — property tests here and `tests/integration_parallel_exec.rs`
//! assert exactly that.
//!
//! Every protocol follows the paper's step structure exactly; block-level
//! math is dispatched through a [`crate::runtime::Backend`] so the same
//! coordinator code runs on the native backend (sweeps) or the PJRT
//! artifacts (serving hot path).

pub mod online;
pub mod picf;
pub mod ppic;
pub mod ppitc;

use crate::cluster::{
    Cluster, FaultPlan, FaultTransport, MachinesLost, NetworkModel,
    ParallelExecutor, RunMetrics,
};
use crate::gp::Prediction;

/// Cluster configuration for a protocol run: how many simulated
/// machines, the modeled interconnect, how node work is *actually*
/// executed on the host (serial, or thread-parallel via
/// [`ParallelExecutor`]), and an optional fault-injection plan.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub machines: usize,
    pub net: NetworkModel,
    pub exec: ParallelExecutor,
    /// When set, runs go through the fault-aware `try_run` protocol
    /// variants over a [`FaultTransport`]; `None` is the direct path.
    pub faults: Option<FaultPlan>,
}

impl ClusterSpec {
    /// Gigabit network model, serial host execution (the seed default).
    pub fn new(machines: usize) -> ClusterSpec {
        ClusterSpec {
            machines,
            net: NetworkModel::gigabit(),
            exec: ParallelExecutor::serial(),
            faults: None,
        }
    }

    /// Gigabit network model with node work executed on `threads` real
    /// host threads (`<= 1` falls back to serial). Each call spawns a
    /// fresh pool; clones of the returned spec share it, so every
    /// protocol run made with one spec (e.g. all methods inside a single
    /// `bench_support::experiments::run_methods` call) reuses the same
    /// threads. Callers looping over many configs should build the spec
    /// once per config, not per protocol run.
    pub fn with_threads(machines: usize, threads: usize) -> ClusterSpec {
        ClusterSpec {
            machines,
            net: NetworkModel::gigabit(),
            exec: ParallelExecutor::threads(threads),
            faults: None,
        }
    }

    /// Attach a fault-injection plan to this spec.
    pub fn with_faults(mut self, plan: FaultPlan) -> ClusterSpec {
        self.faults = Some(plan);
        self
    }

    /// Fresh simulated cluster honoring this spec's executor and, when
    /// a plan is attached, its fault transport.
    pub fn cluster(&self) -> Cluster {
        match &self.faults {
            Some(plan) => Cluster::with_transport(
                self.machines,
                self.net.clone(),
                self.exec.clone(),
                Box::new(FaultTransport::new(plan.clone())),
            ),
            None => Cluster::with_exec(
                self.machines,
                self.net.clone(),
                self.exec.clone(),
            ),
        }
    }
}

/// Result of a protocol run: the predictive distribution (in original
/// test-row order) plus the simulated-run metrics.
#[derive(Debug, Clone)]
pub struct ProtocolOutput {
    pub prediction: Prediction,
    pub metrics: RunMetrics,
}

/// Result of a fault-aware protocol run that completed (possibly
/// degraded): the usual output plus the post-rebalance block state,
/// which the chaos suite audits for exact-once data coverage.
#[derive(Debug, Clone)]
pub struct FaultRun {
    pub output: ProtocolOutput,
    /// Final data-block ownership after any rebalancing (dead machines
    /// own the empty block).
    pub d_blocks: Vec<Vec<usize>>,
    /// Final query-block routing after any re-routing.
    pub u_blocks: Vec<Vec<usize>>,
    /// Machines alive at the end of the run, ascending.
    pub survivors: Vec<usize>,
}

/// Bytes of a f64 payload of `n` elements.
pub(crate) fn f64_bytes(n: usize) -> usize {
    n * std::mem::size_of::<f64>()
}

/// Spread `rows` round-robin across `survivors`' blocks. Returns
/// (adopter id, rows added) for each adopter that received rows.
pub(crate) fn rebalance_rows(
    blocks: &mut [Vec<usize>],
    rows: &[usize],
    survivors: &[usize],
) -> Vec<(usize, usize)> {
    assert!(!survivors.is_empty(), "rebalance with no survivors");
    let mut added = vec![0usize; blocks.len()];
    for (i, &r) in rows.iter().enumerate() {
        let a = survivors[i % survivors.len()];
        blocks[a].push(r);
        added[a] += 1;
    }
    survivors
        .iter()
        .filter(|&&a| added[a] > 0)
        .map(|&a| (a, added[a]))
        .collect()
}

/// Move each dead machine's data rows onto survivors (round-robin),
/// charging each adopter one block fetch of `d_row_bytes` per row.
/// Returns the sorted adopter ids; `Err` when no machine survives.
pub(crate) fn rebalance_dead(
    cluster: &mut Cluster,
    dead: &[usize],
    d_blocks: &mut [Vec<usize>],
    d_row_bytes: usize,
    phase: &str,
) -> Result<Vec<usize>, MachinesLost> {
    if dead.is_empty() {
        return Ok(Vec::new());
    }
    let survivors = cluster.alive_ids();
    if survivors.is_empty() {
        return Err(MachinesLost::at(phase, cluster.size()));
    }
    let mut adopters = Vec::new();
    for &dm in dead {
        let rows = std::mem::take(&mut d_blocks[dm]);
        for (a, count) in rebalance_rows(d_blocks, &rows, &survivors) {
            cluster.rebalance_fetch(a, d_row_bytes * count);
            adopters.push(a);
        }
    }
    adopters.sort_unstable();
    adopters.dedup();
    Ok(adopters)
}

/// Re-route each dead machine's query rows round-robin across
/// survivors (the reporting-side counterpart of [`rebalance_dead`];
/// no-op when nobody survives — the caller errors out separately).
pub(crate) fn reroute_queries_round_robin(
    cluster: &mut Cluster,
    dead: &[usize],
    u_blocks: &mut [Vec<usize>],
    u_row_bytes: usize,
) {
    let survivors = cluster.alive_ids();
    if survivors.is_empty() {
        return;
    }
    for &dm in dead {
        let rows = std::mem::take(&mut u_blocks[dm]);
        for (a, count) in rebalance_rows(u_blocks, &rows, &survivors) {
            cluster.rebalance_fetch(a, u_row_bytes * count);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_spec_default_net() {
        let s = ClusterSpec::new(8);
        assert_eq!(s.machines, 8);
        assert_eq!(s.net, NetworkModel::gigabit());
        assert!(!s.exec.is_parallel());
    }

    #[test]
    fn cluster_spec_threads() {
        let s = ClusterSpec::with_threads(4, 3);
        assert!(s.exec.is_parallel());
        assert_eq!(s.exec.workers(), 3);
        let c = s.cluster();
        assert_eq!(c.size(), 4);
    }

    #[test]
    fn f64_bytes_counts() {
        assert_eq!(f64_bytes(3), 24);
    }

    #[test]
    fn with_faults_builds_fault_cluster() {
        let s = ClusterSpec::new(3)
            .with_faults(FaultPlan::seeded(4).kill(1, "predict"));
        assert!(s.faults.is_some());
        let mut c = s.cluster();
        assert_eq!(c.take_deaths("predict"), vec![1]);
        assert_eq!(c.alive_ids(), vec![0, 2]);
    }

    #[test]
    fn rebalance_rows_round_robin_conserves() {
        let mut blocks = vec![vec![0, 1], vec![], vec![2]];
        let adopted = rebalance_rows(&mut blocks, &[3, 4, 5], &[0, 2]);
        assert_eq!(blocks[0], vec![0, 1, 3, 5]);
        assert_eq!(blocks[2], vec![2, 4]);
        assert_eq!(adopted, vec![(0, 2), (2, 1)]);
    }

    #[test]
    fn rebalance_dead_errors_without_survivors() {
        let s = ClusterSpec::new(2)
            .with_faults(FaultPlan::none().kill(0, "p").kill(1, "p"));
        let mut c = s.cluster();
        let dead = c.take_deaths("p");
        let mut blocks = vec![vec![0], vec![1]];
        let r = rebalance_dead(&mut c, &dead, &mut blocks, 8, "p");
        assert!(r.is_err());
        assert_eq!(r.unwrap_err().phase, "p");
    }
}
