//! The paper's contribution: parallel GP regression protocols over the
//! simulated cluster — pPITC (Section 3), pPIC (Definition 5), and
//! pICF-based GP (Section 4) — plus online/incremental assimilation
//! (§5.2).
//!
//! # Equivalence guarantees (Theorems 1–3)
//!
//! The distributed protocols are *exact reformulations* of their
//! centralized counterparts, not new approximations:
//!
//! * **Theorem 1** — pPITC run on M machines produces the same
//!   predictive mean and variance as centralized PITC
//!   ([`crate::gp::pitc::PitcGp`]) on the same partition.
//! * **Theorem 2** — pPIC likewise equals centralized PIC
//!   ([`crate::gp::pic::PicGp`]), and with M = 1 collapses to the exact
//!   full GP.
//! * **Theorem 3 (§4)** — the pICF-based GP equals the centralized
//!   ICF-based GP ([`crate::gp::icf_gp::IcfGp`]) at the same rank; the
//!   row-based parallel ICF even reproduces the serial factor pivot for
//!   pivot.
//!
//! These identities double as the correctness oracle for *how* the work
//! is executed: whether the simulated machines run one after another or
//! truly concurrently on a [`crate::cluster::ParallelExecutor`] thread
//! pool (set [`ClusterSpec::with_threads`]), predictions must match to
//! ≤1e-10 — property tests here and `tests/integration_parallel_exec.rs`
//! assert exactly that.
//!
//! Every protocol follows the paper's step structure exactly; block-level
//! math is dispatched through a [`crate::runtime::Backend`] so the same
//! coordinator code runs on the native backend (sweeps) or the PJRT
//! artifacts (serving hot path).

pub mod online;
pub mod picf;
pub mod ppic;
pub mod ppitc;

use crate::cluster::{Cluster, NetworkModel, ParallelExecutor, RunMetrics};
use crate::gp::Prediction;

/// Cluster configuration for a protocol run: how many simulated
/// machines, the modeled interconnect, and how node work is *actually*
/// executed on the host (serial, or thread-parallel via
/// [`ParallelExecutor`]).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub machines: usize,
    pub net: NetworkModel,
    pub exec: ParallelExecutor,
}

impl ClusterSpec {
    /// Gigabit network model, serial host execution (the seed default).
    pub fn new(machines: usize) -> ClusterSpec {
        ClusterSpec {
            machines,
            net: NetworkModel::gigabit(),
            exec: ParallelExecutor::serial(),
        }
    }

    /// Gigabit network model with node work executed on `threads` real
    /// host threads (`<= 1` falls back to serial). Each call spawns a
    /// fresh pool; clones of the returned spec share it, so every
    /// protocol run made with one spec (e.g. all methods inside a single
    /// `bench_support::experiments::run_methods` call) reuses the same
    /// threads. Callers looping over many configs should build the spec
    /// once per config, not per protocol run.
    pub fn with_threads(machines: usize, threads: usize) -> ClusterSpec {
        ClusterSpec {
            machines,
            net: NetworkModel::gigabit(),
            exec: ParallelExecutor::threads(threads),
        }
    }

    /// Fresh simulated cluster honoring this spec's executor.
    pub fn cluster(&self) -> Cluster {
        Cluster::with_exec(self.machines, self.net.clone(), self.exec.clone())
    }
}

/// Result of a protocol run: the predictive distribution (in original
/// test-row order) plus the simulated-run metrics.
#[derive(Debug, Clone)]
pub struct ProtocolOutput {
    pub prediction: Prediction,
    pub metrics: RunMetrics,
}

/// Bytes of a f64 payload of `n` elements.
pub(crate) fn f64_bytes(n: usize) -> usize {
    n * std::mem::size_of::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_spec_default_net() {
        let s = ClusterSpec::new(8);
        assert_eq!(s.machines, 8);
        assert_eq!(s.net, NetworkModel::gigabit());
        assert!(!s.exec.is_parallel());
    }

    #[test]
    fn cluster_spec_threads() {
        let s = ClusterSpec::with_threads(4, 3);
        assert!(s.exec.is_parallel());
        assert_eq!(s.exec.workers(), 3);
        let c = s.cluster();
        assert_eq!(c.size(), 4);
    }

    #[test]
    fn f64_bytes_counts() {
        assert_eq!(f64_bytes(3), 24);
    }
}
