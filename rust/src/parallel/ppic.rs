//! pPIC — Definition 5 over the simulated cluster: pPITC's summary
//! machinery plus each machine's local data in its own block prediction,
//! optionally preceded by the parallelized clustering scheme (Remark 2)
//! whose extra O(|D|) time and O((|D|/M)·log M) traffic Table 1 charges.

use super::{
    f64_bytes, rebalance_dead, reroute_queries_round_robin, ClusterSpec,
    FaultRun, ProtocolOutput,
};
use crate::cluster::mpi::MASTER;
use crate::cluster::{Cluster, MachinesLost};
use crate::data::partition::{cluster_partition, random_partition};
use crate::gp::summaries::{LocalSummary, SupportContext};
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::runtime::Backend;
use crate::server::router::Router;
use crate::util::{Pcg64, Stopwatch};

/// Partitioning mode for Step 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioning {
    /// random even partition (no extra cost)
    Random,
    /// the paper's parallelized clustering scheme (charged to the run)
    Clustered,
}

/// Run the pPIC protocol. Returns predictions in original `xu` row order.
///
/// Unlike [`super::ppitc::run`], the partition is produced *inside* the
/// run (seeded by `seed`) because the clustering scheme is part of the
/// protocol and its cost must appear in the metrics.
#[allow(clippy::too_many_arguments)]
pub fn run(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    partitioning: Partitioning,
    seed: u64,
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> ProtocolOutput {
    let m = spec.machines;
    let n = xd.rows;
    let u = xu.rows;
    assert!(n % m == 0 && u % m == 0, "Definition 1 needs m | n and m | u");
    let s = xs.rows;
    let _obsv_span = crate::obsv::span("protocol.pPIC")
        .with_u64("machines", m as u64)
        .with_u64("support", s as u64);
    let mut cluster = spec.cluster();
    // Master-side block math shares the executor's pool (degrades to
    // serial inside node closures / under a serial executor).
    let lctx = spec.exec.linalg_ctx();
    let mut rng = Pcg64::new(seed, 0x9C);

    // STEP 1: partition. The clustering scheme runs across machines —
    // each computes distances for its share of points — so its measured
    // time is divided evenly among nodes, and reassignment is an
    // all-to-all exchange of ~|D|/M + |U|/M points per machine.
    let (d_blocks, u_blocks) = match partitioning {
        Partitioning::Random => {
            (random_partition(n, m, &mut rng), random_partition(u, m, &mut rng))
        }
        Partitioning::Clustered => {
            let (p, secs) =
                Stopwatch::time(|| cluster_partition(xd, xu, m, &mut rng));
            for id in 0..m {
                cluster.charge_compute(id, secs / m as f64);
            }
            let moved_per_pair =
                ((n / m + u / m) * (xd.cols + 1)) / m.max(1);
            cluster.alltoall(f64_bytes(moved_per_pair));
            (p.d_blocks, p.u_blocks)
        }
    };
    cluster.phase("partition");

    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;

    // STEP 2: local summaries.
    let locals = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.local_summary(hyp, &xm, &ym, xs)
    });
    cluster.phase("local_summary");

    // STEP 3: reduce + assimilate + broadcast. The support context and
    // chol(Σ̈_SS) are staged once — every machine already holds Σ_SS
    // and the broadcast global summary, so the hoist adds no traffic
    // (asserted in the metrics tests); it only stops Step 4 from
    // re-factorizing two |S|×|S| matrices per machine.
    cluster.reduce_to_master(f64_bytes(s * s + s));
    let (sctx, global, l_g) = cluster.compute_on(MASTER, || {
        let ctx = SupportContext::new_ctx(&lctx, hyp, xs);
        let refs: Vec<_> = locals.iter().collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let l_g = crate::gp::summaries::chol_global_ctx(&lctx, &global);
        (ctx, global, l_g)
    });
    cluster.bcast_from_master(f64_bytes(s * s + s));
    cluster.phase("global_summary");

    // STEP 4: distributed predictions with local data (Definition 5).
    let preds: Vec<Prediction> = cluster.compute_all(|mid| {
        let xu_m = xu.select_rows(&u_blocks[mid]);
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        let mut p = backend.ppic_predict_staged(hyp, &xu_m, &sctx, &xm,
                                                &ym, &locals[mid], &global,
                                                &l_g);
        p.shift_mean(y_mean);
        p
    });
    cluster.phase("predict");

    let max_u = u_blocks.iter().map(Vec::len).max().unwrap_or(0);
    cluster.gather_to_master(f64_bytes(2 * max_u));
    cluster.phase("collect");

    ProtocolOutput {
        prediction: Prediction::scatter(&preds, &u_blocks, u),
        metrics: cluster.finish(),
    }
}

/// Deterministic variant taking externally-fixed partitions (tests and
/// backend-agreement checks need identical blocks across runs).
#[allow(clippy::too_many_arguments)]
pub fn run_with_partition(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    u_blocks: &[Vec<usize>],
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> ProtocolOutput {
    let s = xs.rows;
    let _obsv_span = crate::obsv::span("protocol.pPIC")
        .with_u64("machines", d_blocks.len() as u64)
        .with_u64("support", s as u64);
    let mut cluster = spec.cluster();
    let lctx = spec.exec.linalg_ctx();
    cluster.phase("partition");
    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
    let locals = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.local_summary(hyp, &xm, &ym, xs)
    });
    cluster.phase("local_summary");
    cluster.reduce_to_master(f64_bytes(s * s + s));
    let (sctx, global, l_g) = cluster.compute_on(MASTER, || {
        let ctx = SupportContext::new_ctx(&lctx, hyp, xs);
        let refs: Vec<_> = locals.iter().collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let l_g = crate::gp::summaries::chol_global_ctx(&lctx, &global);
        (ctx, global, l_g)
    });
    cluster.bcast_from_master(f64_bytes(s * s + s));
    cluster.phase("global_summary");
    let preds: Vec<Prediction> = cluster.compute_all(|mid| {
        let xu_m = xu.select_rows(&u_blocks[mid]);
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        let mut p = backend.ppic_predict_staged(hyp, &xu_m, &sctx, &xm,
                                                &ym, &locals[mid], &global,
                                                &l_g);
        p.shift_mean(y_mean);
        p
    });
    cluster.phase("predict");
    let max_u = u_blocks.iter().map(Vec::len).max().unwrap_or(0);
    cluster.gather_to_master(f64_bytes(2 * max_u));
    cluster.phase("collect");
    ProtocolOutput {
        prediction: Prediction::scatter(&preds, u_blocks, xu.rows),
        metrics: cluster.finish(),
    }
}

/// Fault-aware pPIC over fixed partitions: the same protocol as
/// [`run_with_partition`], mediated by `spec`'s fault transport.
///
/// Rebalance semantics: while the global summary is still open, a dead
/// machine's data rows move round-robin onto survivors and the
/// adopters recompute their local summaries, so the sealed summary
/// still covers every row. *After* the seal the per-machine local
/// blocks backing Definition 5's own-block term are frozen (merging
/// rows then would desynchronize them from the already-computed local
/// summaries); late deaths only move ownership, and their query rows
/// re-route through the [`Router`] to the survivor whose frozen block
/// is most correlated — those queries lose the dead machine's local
/// correction but keep the full global-summary term. With a zero plan
/// the result is bitwise-identical to [`run_with_partition`].
#[allow(clippy::too_many_arguments)]
pub fn try_run_with_partition(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xs: &Mat,
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    u_blocks: &[Vec<usize>],
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> Result<FaultRun, MachinesLost> {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m, "d_blocks vs machines");
    assert_eq!(u_blocks.len(), m, "u_blocks vs machines");
    let s = xs.rows;
    let _obsv_span = crate::obsv::span("protocol.pPIC")
        .with_u64("machines", m as u64)
        .with_u64("support", s as u64);
    let mut cluster = spec.cluster();
    let lctx = spec.exec.linalg_ctx();
    let d_row_bytes = f64_bytes(xd.cols + 1);
    let u_row_bytes = f64_bytes(xu.cols);
    let mut db: Vec<Vec<usize>> = d_blocks.to_vec();
    let mut ub: Vec<Vec<usize>> = u_blocks.to_vec();

    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
    let local_of = |rows: &[usize]| {
        let xm = xd.select_rows(rows);
        let ym: Vec<f64> = rows.iter().map(|&i| y[i] - y_mean).collect();
        backend.local_summary(hyp, &xm, &ym, xs)
    };
    // Post-seal query re-route: nearest frozen survivor block in the
    // kernel metric (the serving-time routing rule).
    let reroute_via_router = |cluster: &mut Cluster,
                              dead: &[usize],
                              ub: &mut Vec<Vec<usize>>,
                              model_blocks: &[Vec<usize>]| {
        let survivors = cluster.alive_ids();
        if survivors.is_empty() {
            return;
        }
        let blks: Vec<Mat> = survivors
            .iter()
            .map(|&a| xd.select_rows(&model_blocks[a]))
            .collect();
        let refs: Vec<&Mat> = blks.iter().collect();
        let router = Router::from_blocks(hyp, &refs);
        for &dm in dead {
            let rows = std::mem::take(&mut ub[dm]);
            if rows.is_empty() {
                continue;
            }
            let mut added = vec![0usize; survivors.len()];
            for &r in &rows {
                let k = router.route(xu.row(r));
                ub[survivors[k]].push(r);
                added[k] += 1;
            }
            for (k, &c) in added.iter().enumerate() {
                if c > 0 {
                    cluster.rebalance_fetch(survivors[k], u_row_bytes * c);
                }
            }
        }
    };

    // Deaths at partition time: rebalance before anyone computes.
    let dead = cluster.take_deaths("partition");
    rebalance_dead(&mut cluster, &dead, &mut db, d_row_bytes, "partition")?;
    reroute_queries_round_robin(&mut cluster, &dead, &mut ub, u_row_bytes);
    cluster.phase("partition");

    let dead = cluster.take_deaths("local_summary");
    rebalance_dead(&mut cluster, &dead, &mut db, d_row_bytes,
                   "local_summary")?;
    reroute_queries_round_robin(&mut cluster, &dead, &mut ub, u_row_bytes);

    // STEP 2: local summaries on the alive machines.
    let mut locals: Vec<Option<LocalSummary>> =
        cluster.compute_alive(|mid| local_of(&db[mid]));
    cluster.phase("local_summary");

    // Deaths on entering Step 3: adopters recompute enlarged summaries.
    let dead = cluster.take_deaths("global_summary");
    for &dm in &dead {
        locals[dm] = None;
    }
    let adopters = rebalance_dead(&mut cluster, &dead, &mut db,
                                  d_row_bytes, "global_summary")?;
    reroute_queries_round_robin(&mut cluster, &dead, &mut ub, u_row_bytes);
    for &a in &adopters {
        locals[a] = Some(cluster.compute_on(a, || local_of(&db[a])));
    }

    // STEP 3: reduce with bounded retry (each round kills ≥1 machine).
    loop {
        let failed = cluster.reduce_to_master(f64_bytes(s * s + s));
        if failed.is_empty() {
            break;
        }
        for &dm in &failed {
            locals[dm] = None;
        }
        let adopters = rebalance_dead(&mut cluster, &failed, &mut db,
                                      d_row_bytes, "global_summary")?;
        reroute_queries_round_robin(&mut cluster, &failed, &mut ub,
                                    u_row_bytes);
        for &a in &adopters {
            locals[a] = Some(cluster.compute_on(a, || local_of(&db[a])));
        }
    }
    let root = cluster.master();
    let (sctx, global, l_g) = cluster.compute_on(root, || {
        let ctx = SupportContext::new_ctx(&lctx, hyp, xs);
        let refs: Vec<&LocalSummary> =
            locals.iter().filter_map(|o| o.as_ref()).collect();
        let global = crate::gp::summaries::global_summary(&ctx, &refs);
        let l_g = crate::gp::summaries::chol_global_ctx(&lctx, &global);
        (ctx, global, l_g)
    });
    // The summary is sealed: freeze the per-machine blocks that back
    // Definition 5's own-block term.
    let model_blocks = db.clone();
    let failed = cluster.bcast_from_master(f64_bytes(s * s + s));
    if !failed.is_empty() {
        for &dm in &failed {
            locals[dm] = None;
        }
        rebalance_dead(&mut cluster, &failed, &mut db, d_row_bytes,
                       "global_summary")?;
        reroute_via_router(&mut cluster, &failed, &mut ub, &model_blocks);
    }
    cluster.phase("global_summary");

    // Deaths on entering Step 4: ownership + router re-route only.
    let dead = cluster.take_deaths("predict");
    rebalance_dead(&mut cluster, &dead, &mut db, d_row_bytes, "predict")?;
    reroute_via_router(&mut cluster, &dead, &mut ub, &model_blocks);

    // STEP 4: distributed predictions with the frozen local blocks.
    let preds = cluster.compute_alive(|mid| {
        let xu_m = xu.select_rows(&ub[mid]);
        let xm = xd.select_rows(&model_blocks[mid]);
        let ym: Vec<f64> =
            model_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        let mut p = backend.ppic_predict_staged(
            hyp, &xu_m, &sctx, &xm, &ym,
            locals[mid].as_ref().expect("alive machine has a summary"),
            &global, &l_g,
        );
        p.shift_mean(y_mean);
        p
    });
    cluster.phase("predict");

    // collect (reporting only): retries re-gather; data still hands on.
    let max_u = ub.iter().map(Vec::len).max().unwrap_or(0);
    loop {
        let failed = cluster.gather_to_master(f64_bytes(2 * max_u));
        if failed.is_empty() {
            break;
        }
        rebalance_dead(&mut cluster, &failed, &mut db, d_row_bytes,
                       "collect")?;
    }
    cluster.phase("collect");

    let survivors = cluster.alive_ids();
    let preds: Vec<Prediction> = preds
        .into_iter()
        .map(|p| p.unwrap_or_else(Prediction::empty))
        .collect();
    Ok(FaultRun {
        output: ProtocolOutput {
            prediction: Prediction::scatter(&preds, &ub, xu.rows),
            metrics: cluster.finish(),
        },
        d_blocks: db,
        u_blocks: ub,
        survivors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::pic::{pic_direct_oracle, PicGp};
    use crate::runtime::NativeBackend;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// THEOREM 2, protocol side: distributed pPIC == centralized PIC ==
    /// the literal eqs. (15)-(16), all on the same partition.
    #[test]
    fn theorem2_ppic_equals_centralized_and_direct() {
        prop_check("thm2-protocol", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = m * g.usize_in(1, 3);
            let s = g.usize_in(2, 5);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xs = Mat::from_vec(s, d, g.uniform_vec(s * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());
            let u_blocks = random_partition(u, m, g.rng());

            let out = run_with_partition(&hyp, &xd, &y, &xs, &xu, &d_blocks,
                                         &u_blocks, &NativeBackend,
                                         &ClusterSpec::new(m));
            let centralized = PicGp::fit(&hyp, &xd, &y, &xs, &d_blocks);
            let want_c = centralized.predict(&xu, &u_blocks);
            assert_all_close(&out.prediction.mean, &want_c.mean, 1e-9, 1e-9);
            assert_all_close(&out.prediction.var, &want_c.var, 1e-9, 1e-9);

            let want_d = pic_direct_oracle(&hyp, &xd, &y, &xs, &xu,
                                           &d_blocks, &u_blocks);
            assert_all_close(&out.prediction.mean, &want_d.mean, 1e-6, 1e-6);
            assert_all_close(&out.prediction.var, &want_d.var, 1e-6, 1e-6);
        });
    }

    /// The clustered run includes the partition phase costs (Table 1's
    /// extra O(|D|) time and alltoall traffic vs random partitioning).
    #[test]
    fn clustering_phase_charged() {
        let mut rng = crate::util::Pcg64::seed(9);
        let (n, u, s, m, d) = (24, 8, 4, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);

        let rand_run = run(&hyp, &xd, &y, &xs, &xu, Partitioning::Random, 1,
                           &NativeBackend, &ClusterSpec::new(m));
        let clus_run = run(&hyp, &xd, &y, &xs, &xu, Partitioning::Clustered, 1,
                           &NativeBackend, &ClusterSpec::new(m));
        // clustered partition phase strictly more expensive
        let rp = rand_run.metrics.phase_duration(0);
        let cp = clus_run.metrics.phase_duration(0);
        assert!(cp > rp, "clustered {cp} vs random {rp}");
        assert!(clus_run.metrics.bytes_sent > rand_run.metrics.bytes_sent);
        // both produce finite predictions over all of U
        assert_eq!(clus_run.prediction.len(), u);
        assert!(clus_run.prediction.mean.iter().all(|v| v.is_finite()));
    }

    /// The staged support-context hoist must not change the per-block
    /// traffic accounting: bytes/messages still follow the Table-1
    /// formula (reduce + bcast of s²+s doubles across m−1 senders,
    /// plus the collect gather of 2·u/m values).
    #[test]
    fn hoist_keeps_traffic_accounting() {
        let mut rng = crate::util::Pcg64::seed(17);
        let (n, u, s, m, d) = (16, 8, 3, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let u_blocks = random_partition(u, m, &mut rng);
        let out = run_with_partition(&hyp, &xd, &y, &xs, &xu, &d_blocks,
                                     &u_blocks, &NativeBackend,
                                     &ClusterSpec::new(m));
        let summary_bytes = 8 * (s * s + s) * (m - 1) * 2;
        let collect_bytes = 8 * 2 * (u / m) * (m - 1);
        assert_eq!(out.metrics.bytes_sent, summary_bytes + collect_bytes);
        let names: Vec<&str> =
            out.metrics.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["partition", "local_summary",
                               "global_summary", "predict", "collect"]);
    }

    /// Exact structural identity: PIC with M = 1 *is* FGP, whatever the
    /// support set — the own-block correction restores Γ_DD + Λ = Σ_DD
    /// and Γ̃_UD = Σ_UD. Strong end-to-end check of the pPIC algebra.
    #[test]
    fn single_machine_ppic_is_fgp() {
        let mut rng = crate::util::Pcg64::seed(13);
        let (n, u, d, s) = (14, 5, 2, 3);
        let hyp = SeArd::isotropic(d, 0.9, 1.3, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xs = Mat::from_vec(s, d, rng.normals(s * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = vec![(0..n).collect::<Vec<_>>()];
        let u_blocks = vec![(0..u).collect::<Vec<_>>()];
        let pic = run_with_partition(&hyp, &xd, &y, &xs, &xu, &d_blocks,
                                     &u_blocks, &NativeBackend,
                                     &ClusterSpec::new(1));
        let fgp = crate::gp::FullGp::fit(&hyp, &xd, &y);
        let want = fgp.predict(&xu);
        assert_all_close(&pic.prediction.mean, &want.mean, 1e-6, 1e-6);
        assert_all_close(&pic.prediction.var, &want.var, 1e-6, 1e-6);
    }
}
