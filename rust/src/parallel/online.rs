//! Online/incremental learning (§5.2): when new data `(D', y_D')`
//! streams in, pPITC/pPIC reuse the local and global summaries of the
//! old data — only the new blocks' summaries are computed and
//! assimilated, skipping the expensive Σ_{D_m D_m|S} inverses of
//! everything already absorbed.
//!
//! Model: each absorbed batch adds one block per machine; machine m's
//! history is a list of blocks, each with its cached local summary. For
//! pPIC prediction, machine m's *local data* is its most recent block
//! (conditional-independence across blocks given S makes this exactly a
//! PIC model whose partition is all absorbed blocks — asserted in tests).

use std::sync::Arc;

use super::{f64_bytes, ClusterSpec, ProtocolOutput};
use crate::cluster::mpi::MASTER;
use crate::gp::predictor::{ppic_operator, PredictOperator};
use crate::gp::summaries::{
    assimilate, chol_global_ctx, GlobalSummary, LocalSummary,
    SupportContext,
};
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::{LinalgCtx, Mat};
use crate::runtime::Backend;

/// Streaming pPITC/pPIC state: summaries persist across batches.
///
/// The absorb/predict loop (§5.2): each machine summarizes only its
/// *new* block, the master assimilates those summaries into the running
/// global summary, and predictions are always available from the current
/// state. With a thread-backed [`ClusterSpec`]
/// ([`ClusterSpec::with_threads`]) the per-machine summaries of each
/// batch are computed concurrently on the host.
///
/// ```
/// use std::sync::Arc;
/// use pgpr::kernel::SeArd;
/// use pgpr::linalg::Mat;
/// use pgpr::parallel::online::OnlineGp;
/// use pgpr::parallel::ClusterSpec;
/// use pgpr::runtime::NativeBackend;
///
/// // two machines, 1-D inputs, a 3-point support set
/// let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.1);
/// let xs = Mat::from_vec(3, 1, vec![-1.0, 0.0, 1.0]);
/// let mut gp = OnlineGp::new(&hyp, &xs, Arc::new(NativeBackend),
///                            ClusterSpec::new(2));
///
/// // a batch streams in: one (inputs, outputs) block per machine
/// let batch = vec![
///     (Mat::from_vec(2, 1, vec![-0.5, -0.2]), vec![0.30, 0.10]),
///     (Mat::from_vec(2, 1, vec![0.2, 0.6]), vec![-0.10, -0.40]),
/// ];
/// gp.absorb(&batch);          // costs only the new blocks' summaries
///
/// // predict anywhere, any time; test rows are split across machines
/// let xu = Mat::from_vec(2, 1, vec![0.0, 0.4]);
/// let u_blocks = vec![vec![0], vec![1]];
/// let out = gp.predict_ppitc(&xu, &u_blocks);
/// assert_eq!(out.prediction.len(), 2);
/// assert!(out.prediction.var.iter().all(|&v| v > 0.0));
///
/// // keep streaming: later batches reuse everything absorbed so far
/// gp.absorb(&batch);
/// assert_eq!(gp.batches, 2);
/// ```
pub struct OnlineGp {
    hyp: SeArd,
    xs: Mat,
    backend: Arc<dyn Backend>,
    spec: ClusterSpec,
    /// the fixed prior mean (set from the first batch)
    y_mean: Option<f64>,
    global: Option<GlobalSummary>,
    /// Support context, built once at the first absorb and reused by
    /// every later absorb and predict (the staged-factor hoist: the
    /// unstaged path re-factorized Σ_SS per machine per predict).
    sctx: Option<SupportContext>,
    /// chol(Σ̈_SS) of the *current* global summary, refreshed per
    /// absorb so predictions never re-factorize it.
    l_g: Option<Mat>,
    /// machine m's latest block (inputs, centered outputs, summary)
    latest: Vec<Option<(Mat, Vec<f64>, LocalSummary)>>,
    /// number of absorbed batches
    pub batches: usize,
    /// cumulative simulated seconds spent absorbing
    pub absorb_makespan: f64,
}

impl OnlineGp {
    pub fn new(hyp: &SeArd, xs: &Mat, backend: Arc<dyn Backend>,
               spec: ClusterSpec) -> OnlineGp {
        let m = spec.machines;
        OnlineGp {
            hyp: hyp.clone(),
            xs: xs.clone(),
            backend,
            spec,
            y_mean: None,
            global: None,
            sctx: None,
            l_g: None,
            latest: (0..m).map(|_| None).collect(),
            batches: 0,
            absorb_makespan: 0.0,
        }
    }

    pub fn machines(&self) -> usize {
        self.spec.machines
    }

    // --- checkpoint support (PR 10, `crate::store`) -----------------
    //
    // The durable stream state is exactly: y_mean, the assimilated
    // global summary, its Cholesky factor, and each machine's latest
    // block. `sctx` is a pure function of (hyp, xs) and is recomputed
    // at restore with the *same* execution context `absorb` uses, so
    // the restored factors are bitwise what the original process held.
    // `absorb_makespan` is wall-clock measurement, not model state,
    // and deliberately restarts at zero.

    pub(crate) fn stream_y_mean(&self) -> Option<f64> {
        self.y_mean
    }

    pub(crate) fn stream_global(&self) -> Option<&GlobalSummary> {
        self.global.as_ref()
    }

    pub(crate) fn stream_l_g(&self) -> Option<&Mat> {
        self.l_g.as_ref()
    }

    pub(crate) fn stream_latest(&self)
        -> &[Option<(Mat, Vec<f64>, LocalSummary)>]
    {
        &self.latest
    }

    /// Rebuild an [`OnlineGp`] from checkpointed stream state; the next
    /// [`OnlineGp::absorb`] continues bitwise-identically to a process
    /// that never stopped (pinned in `tests/integration_store.rs`).
    /// A non-SPD support matrix (possible only in a crafted checkpoint)
    /// is reported, not panicked on.
    pub(crate) fn restore(
        hyp: &SeArd,
        xs: &Mat,
        backend: Arc<dyn Backend>,
        spec: ClusterSpec,
        y_mean: Option<f64>,
        global: Option<GlobalSummary>,
        l_g: Option<Mat>,
        latest: Vec<Option<(Mat, Vec<f64>, LocalSummary)>>,
        batches: usize,
    ) -> Result<OnlineGp, crate::linalg::cholesky::NotSpd> {
        assert_eq!(latest.len(), spec.machines, "one latest slot per machine");
        let sctx = if global.is_some() {
            let lctx = spec.exec.linalg_ctx();
            Some(SupportContext::try_new_ctx(&lctx, hyp, xs)?)
        } else {
            None
        };
        Ok(OnlineGp {
            hyp: hyp.clone(),
            xs: xs.clone(),
            backend,
            spec,
            y_mean,
            global,
            sctx,
            l_g,
            latest,
            batches,
            absorb_makespan: 0.0,
        })
    }

    /// Absorb one batch: `blocks[m]` is machine m's new local data.
    /// Costs only the new blocks' summaries + one reduce (no recompute
    /// of history) — the §5.2 saving.
    pub fn absorb(&mut self, blocks: &[(Mat, Vec<f64>)]) -> f64 {
        let m = self.spec.machines;
        assert_eq!(blocks.len(), m, "one block per machine");
        if self.y_mean.is_none() {
            // prior mean from the first batch only (fixed thereafter —
            // matching the batch runs it is compared against)
            let total: f64 = blocks.iter().map(|(_, y)| y.iter().sum::<f64>()).sum();
            let count: usize = blocks.iter().map(|(_, y)| y.len()).sum();
            self.y_mean = Some(total / count.max(1) as f64);
        }
        let y_mean = self.y_mean.unwrap();
        let mut cluster = self.spec.cluster();
        let s = self.xs.rows;

        let locals: Vec<LocalSummary> = cluster.compute_all(|mid| {
            let (xm, ym) = &blocks[mid];
            let centered: Vec<f64> = ym.iter().map(|v| v - y_mean).collect();
            self.backend.local_summary(&self.hyp, xm, &centered, &self.xs)
        });
        cluster.reduce_to_master(f64_bytes(s * s + s));
        cluster.compute_on(MASTER, || {
            let lctx = self.spec.exec.linalg_ctx();
            if self.sctx.is_none() {
                self.sctx =
                    Some(SupportContext::new_ctx(&lctx, &self.hyp, &self.xs));
            }
            match &mut self.global {
                Some(g) => {
                    for l in &locals {
                        assimilate(g, l);
                    }
                }
                None => {
                    let refs: Vec<_> = locals.iter().collect();
                    self.global = Some(crate::gp::summaries::global_summary(
                        self.sctx.as_ref().unwrap(), &refs));
                }
            }
            // refresh chol(Σ̈_SS) once per absorb so every later
            // predict (and operator staging) reuses it
            self.l_g = Some(chol_global_ctx(&lctx,
                                            self.global.as_ref().unwrap()));
        });
        cluster.bcast_from_master(f64_bytes(s * s + s));

        for (mid, ((xm, ym), loc)) in
            blocks.iter().zip(locals.into_iter()).enumerate()
        {
            let centered: Vec<f64> = ym.iter().map(|v| v - y_mean).collect();
            self.latest[mid] = Some((xm.clone(), centered, loc));
        }
        self.batches += 1;
        let metrics = cluster.finish();
        self.absorb_makespan += metrics.makespan;
        metrics.makespan
    }

    /// Stage the per-machine serve-path operators from the *current*
    /// summaries (pPIC flavor: machine m's local term is its latest
    /// block). Each operator equals [`OnlineGp::predict_ppic`] on that
    /// machine's rows ≤1e-12; callers must restage after an absorb
    /// (the facade's `OnlineSession` invalidates automatically).
    pub fn machine_operators(&self, lctx: &LinalgCtx)
        -> Vec<PredictOperator>
    {
        let global = self.global.as_ref().expect("absorb before predict");
        let sctx = self.sctx.as_ref().expect("absorb before predict");
        let l_g = self.l_g.as_ref().expect("absorb before predict");
        let y_mean = self.y_mean.unwrap();
        self.latest
            .iter()
            .map(|slot| {
                let (xm, ym, loc) =
                    slot.as_ref().expect("machine has no data");
                ppic_operator(lctx, &self.hyp, sctx, global, l_g, xm, ym,
                              loc, y_mean)
            })
            .collect()
    }

    /// pPITC prediction from the current summaries.
    pub fn predict_ppitc(&self, xu: &Mat, u_blocks: &[Vec<usize>])
        -> ProtocolOutput
    {
        let global = self.global.as_ref().expect("absorb before predict");
        let sctx = self.sctx.as_ref().expect("absorb before predict");
        let l_g = self.l_g.as_ref().expect("absorb before predict");
        let y_mean = self.y_mean.unwrap();
        let _obsv_span = crate::obsv::span("protocol.online")
            .with_str("variant", "pPITC")
            .with_u64("machines", self.spec.machines as u64);
        let mut cluster = self.spec.cluster();
        let preds: Vec<Prediction> = cluster.compute_all(|mid| {
            let xu_m = xu.select_rows(&u_blocks[mid]);
            let mut p = self.backend.ppitc_predict_staged(&self.hyp, &xu_m,
                                                          sctx, global, l_g);
            p.shift_mean(y_mean);
            p
        });
        cluster.phase("predict");
        let max_u = u_blocks.iter().map(Vec::len).max().unwrap_or(0);
        cluster.gather_to_master(f64_bytes(2 * max_u));
        ProtocolOutput {
            prediction: Prediction::scatter(&preds, u_blocks, xu.rows),
            metrics: cluster.finish(),
        }
    }

    /// pPIC prediction: machine m's local term uses its latest block.
    pub fn predict_ppic(&self, xu: &Mat, u_blocks: &[Vec<usize>])
        -> ProtocolOutput
    {
        let global = self.global.as_ref().expect("absorb before predict");
        let sctx = self.sctx.as_ref().expect("absorb before predict");
        let l_g = self.l_g.as_ref().expect("absorb before predict");
        let y_mean = self.y_mean.unwrap();
        let _obsv_span = crate::obsv::span("protocol.online")
            .with_str("variant", "pPIC")
            .with_u64("machines", self.spec.machines as u64);
        let mut cluster = self.spec.cluster();
        let preds: Vec<Prediction> = cluster.compute_all(|mid| {
            let (xm, ym, loc) =
                self.latest[mid].as_ref().expect("machine has no data");
            let xu_m = xu.select_rows(&u_blocks[mid]);
            let mut p = self.backend.ppic_predict_staged(&self.hyp, &xu_m,
                                                         sctx, xm, ym, loc,
                                                         global, l_g);
            p.shift_mean(y_mean);
            p
        });
        cluster.phase("predict");
        let max_u = u_blocks.iter().map(Vec::len).max().unwrap_or(0);
        cluster.gather_to_master(f64_bytes(2 * max_u));
        ProtocolOutput {
            prediction: Prediction::scatter(&preds, u_blocks, xu.rows),
            metrics: cluster.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::runtime::NativeBackend;
    use crate::testkit::assert_all_close;
    use crate::util::Pcg64;

    fn setup(n_per_block: usize, m: usize, batches: usize, d: usize, seed: u64)
        -> (SeArd, Mat, Vec<Vec<(Mat, Vec<f64>)>>, Mat)
    {
        let mut rng = Pcg64::seed(seed);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xs = Mat::from_vec(4, d, rng.normals(4 * d));
        let mut all_batches = Vec::new();
        for _ in 0..batches {
            let mut batch = Vec::new();
            for _ in 0..m {
                let xm = Mat::from_vec(n_per_block, d,
                                       rng.normals(n_per_block * d));
                // zero-mean per block so the online prior mean (from the
                // first batch) and any batch run's empirical mean agree
                // exactly — keeps the equivalence tests exact.
                let mut ym = rng.normals(n_per_block);
                let mu = ym.iter().sum::<f64>() / ym.len() as f64;
                for v in ym.iter_mut() {
                    *v -= mu;
                }
                batch.push((xm, ym));
            }
            all_batches.push(batch);
        }
        let xu = Mat::from_vec(6, d, rng.normals(6 * d));
        (hyp, xs, all_batches, xu)
    }

    /// §5.2 correctness: online absorption over two batches equals the
    /// batch pPITC run whose partition is all 2M blocks.
    #[test]
    fn online_ppitc_equals_batch_with_refined_partition() {
        let (m, per, d) = (3, 4, 2);
        let (hyp, xs, batches, xu) = setup(per, m, 2, d, 42);
        let spec = ClusterSpec::new(m);
        let mut online = OnlineGp::new(&hyp, &xs, std::sync::Arc::new(NativeBackend),
                                       spec.clone());
        for b in &batches {
            online.absorb(b);
        }
        let u_blocks = random_partition(xu.rows, m, &mut Pcg64::seed(1));
        let got = online.predict_ppitc(&xu, &u_blocks);

        // batch equivalent: concatenate all blocks, partition = 2M blocks
        let mut xd_rows = Vec::new();
        let mut y_all = Vec::new();
        let mut d_blocks = Vec::new();
        let mut offset = 0;
        for b in &batches {
            for (xm, ym) in b {
                let rows: Vec<usize> = (offset..offset + xm.rows).collect();
                offset += xm.rows;
                d_blocks.push(rows);
                for r in 0..xm.rows {
                    xd_rows.push(xm.row(r).to_vec());
                }
                y_all.extend_from_slice(ym);
            }
        }
        let xd = Mat::from_rows(&xd_rows);
        // per-block zero means (see setup) make the online prior mean and
        // the batch run's empirical mean both exactly zero.
        let batch_u_blocks: Vec<Vec<usize>> = std::iter::once(u_blocks.concat())
            .chain((1..d_blocks.len()).map(|_| Vec::new()))
            .collect();
        let want = crate::parallel::ppitc::run(
            &hyp, &xd, &y_all, &xs, &xu, &d_blocks, &batch_u_blocks,
            &NativeBackend, &ClusterSpec::new(d_blocks.len()),
        );
        assert_all_close(&got.prediction.mean, &want.prediction.mean, 1e-8, 1e-8);
        assert_all_close(&got.prediction.var, &want.prediction.var, 1e-8, 1e-8);
    }

    /// The incremental absorb must be cheaper than recomputing the full
    /// history every time (the §5.2 claim).
    #[test]
    fn absorb_cost_does_not_grow_with_history() {
        let (m, per, d) = (2, 16, 2);
        let (hyp, xs, batches, _) = setup(per, m, 4, d, 7);
        let mut online = OnlineGp::new(&hyp, &xs, std::sync::Arc::new(NativeBackend),
                                       ClusterSpec::new(m));
        let mut costs = Vec::new();
        for b in &batches {
            costs.push(online.absorb(b));
        }
        // each absorb handles one batch of identical size: cost should be
        // flat (within noise), definitely not linear in batch index
        let first = costs[0];
        let last = *costs.last().unwrap();
        assert!(last < first * 5.0,
                "absorb cost grew: first {first} last {last} ({costs:?})");
        assert_eq!(online.batches, 4);
    }

    /// pPIC predictions from the online state are finite and bounded.
    #[test]
    fn online_ppic_sane() {
        let (m, per, d) = (2, 5, 2);
        let (hyp, xs, batches, xu) = setup(per, m, 2, d, 9);
        let mut online = OnlineGp::new(&hyp, &xs, std::sync::Arc::new(NativeBackend),
                                       ClusterSpec::new(m));
        for b in &batches {
            online.absorb(b);
        }
        let u_blocks = random_partition(xu.rows, m, &mut Pcg64::seed(2));
        let out = online.predict_ppic(&xu, &u_blocks);
        assert_eq!(out.prediction.len(), xu.rows);
        for i in 0..xu.rows {
            assert!(out.prediction.mean[i].is_finite());
            assert!(out.prediction.var[i].is_finite());
            assert!(out.prediction.var[i] > 0.0);
        }
    }

    #[test]
    #[should_panic]
    fn predict_before_absorb_panics() {
        let hyp = SeArd::isotropic(1, 1.0, 1.0, 0.1);
        let xs = Mat::from_vec(2, 1, vec![0.0, 1.0]);
        let online = OnlineGp::new(&hyp, &xs, std::sync::Arc::new(NativeBackend),
                                   ClusterSpec::new(1));
        let xu = Mat::from_vec(1, 1, vec![0.5]);
        online.predict_ppitc(&xu, &[vec![0]]);
    }
}
