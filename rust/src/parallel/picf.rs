//! pICF-based GP — Section 4, Steps 1–6, over the simulated cluster,
//! including the **row-based parallel ICF** of Chang et al. (2007):
//! machine m owns column block D_m of the factor; every iteration
//! all-reduces the pivot choice, broadcasts the pivot input and the
//! pivot's factor-column prefix, and each machine updates its slab —
//! O(R²·log M) communication, matching Table 1.

use super::{f64_bytes, ClusterSpec, FaultRun, ProtocolOutput};
use crate::cluster::mpi::MASTER;
use crate::cluster::{Cluster, MachinesLost};
use crate::gp::summaries::{IcfGlobalSummary, IcfLocalSummary};
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::runtime::Backend;

/// One completed iteration of the row-based parallel ICF: everything a
/// survivor needs to rebuild any machine's factor column
/// bitwise-exactly (the pivot's global row, its pinned diagonal value,
/// and the broadcast factor prefix).
#[derive(Debug, Clone)]
pub struct PivotRecord {
    pub global: usize,
    pub piv: f64,
    pub prefix: Vec<f64>,
}

/// Rebuild factor column `gi` (plus its residual) by replaying the
/// pivot records — the exact recurrence the owning machine ran, so the
/// rebuilt column is bitwise-identical to the lost one.
fn rebuilt_column(
    hyp: &SeArd,
    xd: &Mat,
    records: &[PivotRecord],
    gi: usize,
) -> (Vec<f64>, f64) {
    let x_c = xd.row(gi);
    let mut col = vec![0.0; records.len()];
    let mut resid = hyp.sf2();
    for (k, rec) in records.iter().enumerate() {
        let x_piv = xd.row(rec.global);
        let mut v = hyp.k(x_piv, x_c);
        for (t, &pf) in rec.prefix.iter().enumerate() {
            v -= pf * col[t];
        }
        let mut val = v / rec.piv;
        if rec.global == gi {
            val = rec.piv; // the pin (mirrors linalg::icf)
        }
        col[k] = val;
        resid -= val * val;
        if rec.global == gi {
            resid = 0.0;
        }
    }
    (col, resid)
}

/// Move each dead machine's factor columns onto survivors: rows go
/// round-robin, each adopter pays one block fetch and rebuilds the
/// adopted columns (and residuals, when mid-factorization) from the
/// pivot records. Returns the sorted adopter ids.
#[allow(clippy::too_many_arguments)]
fn adopt_columns(
    cluster: &mut Cluster,
    dead: &[usize],
    db: &mut [Vec<usize>],
    slabs: &mut [Mat],
    mut resid: Option<&mut [Vec<f64>]>,
    records: &[PivotRecord],
    hyp: &SeArd,
    xd: &Mat,
    rank: usize,
    phase: &str,
) -> Result<Vec<usize>, MachinesLost> {
    if dead.is_empty() {
        return Ok(Vec::new());
    }
    let survivors = cluster.alive_ids();
    if survivors.is_empty() {
        return Err(MachinesLost::at(phase, cluster.size()));
    }
    let d_row_bytes = f64_bytes(xd.cols + 1);
    let mut adopters = Vec::new();
    for &dm in dead {
        let rows = std::mem::take(&mut db[dm]);
        slabs[dm] = Mat::zeros(rank, 0);
        if let Some(r) = resid.as_deref_mut() {
            r[dm].clear();
        }
        let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); survivors.len()];
        for (i, &gi) in rows.iter().enumerate() {
            assigned[i % survivors.len()].push(gi);
        }
        for (j, new_rows) in assigned.into_iter().enumerate() {
            if new_rows.is_empty() {
                continue;
            }
            let a = survivors[j];
            cluster.rebalance_fetch(a, d_row_bytes * new_rows.len());
            let rebuilt: Vec<(Vec<f64>, f64)> = cluster.compute_on(a, || {
                new_rows
                    .iter()
                    .map(|&gi| rebuilt_column(hyp, xd, records, gi))
                    .collect()
            });
            let old = slabs[a].cols;
            let mut grown = Mat::zeros(rank, old + rebuilt.len());
            for t in 0..rank {
                for c in 0..old {
                    grown[(t, c)] = slabs[a][(t, c)];
                }
                for (c, (col, _)) in rebuilt.iter().enumerate() {
                    if t < col.len() {
                        grown[(t, old + c)] = col[t];
                    }
                }
            }
            slabs[a] = grown;
            if let Some(r) = resid.as_deref_mut() {
                r[a].extend(rebuilt.iter().map(|(_, res)| *res));
            }
            db[a].extend(new_rows);
            adopters.push(a);
        }
    }
    adopters.sort_unstable();
    adopters.dedup();
    Ok(adopters)
}

/// Distributed row-based parallel ICF (Step 2).
///
/// Returns machine m's slab `F_m ∈ R^{R×|D_m|}` of the factor of the
/// *noise-free* K_DD, where columns follow `d_blocks[m]` order. The
/// communication per iteration k is: one allreduce of the (value, owner)
/// pivot candidate + one broadcast of the pivot's input row (d floats)
/// and factor prefix (k floats).
pub fn parallel_icf(
    hyp: &SeArd,
    xd: &Mat,
    d_blocks: &[Vec<usize>],
    rank: usize,
    cluster: &mut Cluster,
) -> Vec<Mat> {
    let d = xd.cols;
    let rank = rank.min(xd.rows);

    // per-machine state: residual diagonals + slab rows built so far
    let mut resid: Vec<Vec<f64>> =
        d_blocks.iter().map(|b| vec![hyp.sf2(); b.len()]).collect();
    let mut slabs: Vec<Mat> =
        d_blocks.iter().map(|b| Mat::zeros(rank, b.len())).collect();

    for k in 0..rank {
        // (a) local pivot candidates — measured per machine. Ties break
        // toward the smallest *global* index, matching linalg::icf so
        // the distributed factor is bit-identical to the serial one.
        // Inline even under a thread-backed executor: this scan is a
        // microsecond-scale fold issued `rank` times, where pool
        // dispatch would cost more than the work (the heavy step (d)
        // slab update below does fan out).
        let candidates: Vec<(f64, usize)> = cluster.compute_all_inline(|mid| {
            let blk = &d_blocks[mid];
            resid[mid]
                .iter()
                .enumerate()
                .fold((f64::NEG_INFINITY, 0usize), |acc, (i, &v)| {
                    let better = v > acc.0
                        || (v == acc.0 && blk[i] < blk[acc.1]);
                    if better { (v, i) } else { acc }
                })
        });
        // (b) allreduce MAXLOC of the (value, owner) candidate — one
        // butterfly collective (MPI_Allreduce), 16 bytes
        cluster.allreduce(16);
        let (owner, local_i) = candidates.iter().enumerate().fold(
            (0usize, candidates[0].1),
            |(bm, bi), (mid, &(v, i))| {
                let (bv, bg) = (candidates[bm].0, d_blocks[bm][bi]);
                let better = v > bv || (v == bv && d_blocks[mid][i] < bg);
                if better { (mid, i) } else { (bm, bi) }
            },
        );
        let pivot_global = d_blocks[owner][local_i];
        let piv_val = candidates[owner].0;
        if piv_val <= 0.0 {
            break; // numerically exhausted — slabs keep zero rows
        }
        let piv = piv_val.sqrt();

        // (c) owner broadcasts x_pivot (d floats) + its factor prefix
        // F[0..k, pivot] (k floats)
        let prefix: Vec<f64> =
            (0..k).map(|t| slabs[owner][(t, local_i)]).collect();
        cluster.bcast_from_master(f64_bytes(d + k));

        // (d) every machine updates its slab row k — measured
        let x_piv: Vec<f64> = xd.row(pivot_global).to_vec();
        let mut updates: Vec<Vec<f64>> = cluster.compute_all(|mid| {
            let blk = &d_blocks[mid];
            let slab = &slabs[mid];
            let mut row = vec![0.0; blk.len()];
            for (c, &gi) in blk.iter().enumerate() {
                let mut v = hyp.k(&x_piv, xd.row(gi));
                for (t, &pf) in prefix.iter().enumerate() {
                    v -= pf * slab[(t, c)];
                }
                row[c] = v / piv;
            }
            row
        });
        // pin the pivot entry to piv exactly (mirrors linalg::icf — keeps
        // the residual streams of serial and distributed runs bitwise
        // identical so they stop at the same step)
        updates[owner][local_i] = piv;
        for (mid, row) in updates.into_iter().enumerate() {
            for (c, v) in row.into_iter().enumerate() {
                slabs[mid][(k, c)] = v;
                resid[mid][c] -= slabs[mid][(k, c)] * slabs[mid][(k, c)];
            }
        }
        // pivot column residual is exactly zero
        resid[owner][local_i] = 0.0;
    }
    slabs
}

/// Run the full pICF-based GP protocol (Steps 2–6).
#[allow(clippy::too_many_arguments)]
pub fn run(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    rank: usize,
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> ProtocolOutput {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m);
    let u = xu.rows;
    let _obsv_span = crate::obsv::span("protocol.pICF")
        .with_u64("machines", m as u64)
        .with_u64("rank", rank as u64);
    let mut cluster = spec.cluster();
    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;

    // STEP 2: row-based parallel ICF.
    let slabs = parallel_icf(hyp, xd, d_blocks, rank, &mut cluster);
    let r = slabs[0].rows;
    cluster.phase("parallel_icf");

    // STEP 3: local summaries.
    let locals: Vec<IcfLocalSummary> = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.icf_local(hyp, &xm, &ym, xu, &slabs[mid])
    });
    // gather to master: (R² + R·U + R) doubles per machine
    cluster.gather_to_master(f64_bytes(r * r + r * u + r));
    cluster.phase("icf_local");

    // STEP 4: master builds + broadcasts the global summary.
    let global: IcfGlobalSummary = cluster.compute_on(MASTER, || {
        let mut sum_y = vec![0.0; r];
        let mut sum_s = Mat::zeros(r, u);
        let mut sum_phi = Mat::zeros(r, r);
        for l in &locals {
            for i in 0..r {
                sum_y[i] += l.y_dot[i];
            }
            sum_s.add_assign(&l.s_dot);
            sum_phi.add_assign(&l.phi);
        }
        backend.icf_global(hyp, &sum_y, &sum_s, &sum_phi)
    });
    cluster.bcast_from_master(f64_bytes(r * u + r));
    cluster.phase("icf_global");

    // STEP 5: predictive components.
    let comps: Vec<Prediction> = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.icf_predict(hyp, xu, &xm, &ym, &locals[mid].s_dot, &global)
    });
    cluster.gather_to_master(f64_bytes(2 * u));
    cluster.phase("icf_components");

    // STEP 6: master finalizes.
    let mut prediction = cluster.compute_on(MASTER, || {
        let refs: Vec<&Prediction> = comps.iter().collect();
        crate::gp::summaries::icf_finalize(hyp, u, &refs)
    });
    prediction.shift_mean(y_mean);
    cluster.phase("finalize");

    ProtocolOutput { prediction, metrics: cluster.finish() }
}

/// Local pivot-candidate scan over the machines still alive (step (a)
/// of the fault-aware factorization). `-inf` marks an empty block.
fn scan_candidates(
    cluster: &mut Cluster,
    db: &[Vec<usize>],
    resid: &[Vec<f64>],
) -> Vec<Option<(f64, usize)>> {
    cluster.compute_alive_inline(|mid| {
        let blk = &db[mid];
        resid[mid]
            .iter()
            .enumerate()
            .fold((f64::NEG_INFINITY, 0usize), |acc, (i, &v)| {
                let better =
                    v > acc.0 || (v == acc.0 && blk[i] < blk[acc.1]);
                if better { (v, i) } else { acc }
            })
    })
}

/// Fault-aware row-based parallel ICF: the statement-for-statement
/// mirror of [`parallel_icf`] with bounded-retry collectives. A machine
/// that exhausts its retries (or is scheduled to die at phase
/// `"parallel_icf"`) drops out mid-factorization; its columns are
/// rebuilt bitwise on survivors from the pivot records *as of before
/// the in-flight iteration*, so the surviving factor is exactly the one
/// the fault-free run produces. Returns the slabs plus the records
/// (later phases use them to adopt columns of machines dying then).
pub fn parallel_icf_ft(
    hyp: &SeArd,
    xd: &Mat,
    db: &mut [Vec<usize>],
    rank: usize,
    cluster: &mut Cluster,
) -> Result<(Vec<Mat>, Vec<PivotRecord>), MachinesLost> {
    let d = xd.cols;
    let rank = rank.min(xd.rows);

    let mut resid: Vec<Vec<f64>> =
        db.iter().map(|b| vec![hyp.sf2(); b.len()]).collect();
    let mut slabs: Vec<Mat> =
        db.iter().map(|b| Mat::zeros(rank, b.len())).collect();
    let mut records: Vec<PivotRecord> = Vec::new();

    // deaths scheduled at factorization entry: no factor state exists
    // yet, so adoption just moves the data rows
    let dead = cluster.take_deaths("parallel_icf");
    adopt_columns(cluster, &dead, db, &mut slabs, Some(&mut resid),
                  &records, hyp, xd, rank, "parallel_icf")?;

    for k in 0..rank {
        // (a) candidates on the machines still alive
        let mut candidates = scan_candidates(cluster, db, &resid);

        // (b) allreduce MAXLOC with bounded retries; a machine that
        // exhausts them dies, its columns move, and the scan re-runs
        loop {
            let failed = cluster.allreduce(16);
            if failed.is_empty() {
                break;
            }
            adopt_columns(cluster, &failed, db, &mut slabs,
                          Some(&mut resid), &records, hyp, xd, rank,
                          "parallel_icf")?;
            candidates = scan_candidates(cluster, db, &resid);
        }
        // MAXLOC over the alive candidates; skipping the -inf sentinel
        // also guards the empty-block indexing panic the plain fold
        // would hit when machine 0 owns no columns
        let mut best: Option<(f64, usize, usize)> = None;
        for (mid, cand) in candidates.iter().enumerate() {
            let (v, i) = match cand {
                Some(c) => (c.0, c.1),
                None => continue,
            };
            if v == f64::NEG_INFINITY {
                continue;
            }
            let better = match best {
                None => true,
                Some((bv, bm, bi)) => {
                    v > bv || (v == bv && db[mid][i] < db[bm][bi])
                }
            };
            if better {
                best = Some((v, mid, i));
            }
        }
        let (piv_val, mut owner, mut local_i) = match best {
            Some(b) => b,
            None => break, // no columns left anywhere
        };
        if piv_val <= 0.0 {
            break; // numerically exhausted — slabs keep zero rows
        }
        let pivot_global = db[owner][local_i];
        let piv = piv_val.sqrt();

        // (c) broadcast of x_pivot + factor prefix, bounded retries. A
        // receiver dying here hands its columns on *before* update k is
        // applied — and the pivot owner itself may be among the dead,
        // so re-locate the pivot column afterwards.
        let prefix: Vec<f64> =
            (0..k).map(|t| slabs[owner][(t, local_i)]).collect();
        let failed = cluster.bcast_from_master(f64_bytes(d + k));
        if !failed.is_empty() {
            adopt_columns(cluster, &failed, db, &mut slabs,
                          Some(&mut resid), &records, hyp, xd, rank,
                          "parallel_icf")?;
            let mut found = None;
            'relocate: for (mid, blk) in db.iter().enumerate() {
                if !cluster.is_alive(mid) {
                    continue;
                }
                for (ci, &g) in blk.iter().enumerate() {
                    if g == pivot_global {
                        found = Some((mid, ci));
                        break 'relocate;
                    }
                }
            }
            let (o, li) =
                found.expect("pivot column must survive adoption");
            owner = o;
            local_i = li;
        }

        // (d) alive machines update their slab row k
        let x_piv: Vec<f64> = xd.row(pivot_global).to_vec();
        let mut updates: Vec<Option<Vec<f64>>> =
            cluster.compute_alive(|mid| {
                let blk = &db[mid];
                let slab = &slabs[mid];
                let mut row = vec![0.0; blk.len()];
                for (c, &gi) in blk.iter().enumerate() {
                    let mut v = hyp.k(&x_piv, xd.row(gi));
                    for (t, &pf) in prefix.iter().enumerate() {
                        v -= pf * slab[(t, c)];
                    }
                    row[c] = v / piv;
                }
                row
            });
        if let Some(row) = updates[owner].as_mut() {
            row[local_i] = piv;
        }
        for (mid, row) in updates.into_iter().enumerate() {
            if let Some(row) = row {
                for (c, v) in row.into_iter().enumerate() {
                    slabs[mid][(k, c)] = v;
                    resid[mid][c] -=
                        slabs[mid][(k, c)] * slabs[mid][(k, c)];
                }
            }
        }
        resid[owner][local_i] = 0.0;
        // pushed *after* update k: a mid-iteration adoption rebuilds
        // state as of before this row, and the adopter then applies
        // update k through the normal step (d) path
        records.push(PivotRecord { global: pivot_global, piv, prefix });
    }
    Ok((slabs, records))
}

/// Fault-aware pICF protocol (Steps 2–6): mirrors [`run`] with
/// scheduled-death and retry-exhaustion handling at every phase. Lost
/// factor columns are rebuilt bitwise from the pivot records; before
/// the global summary is sealed adopters recompute their merged local
/// summaries, and after the seal they recompute their merged component
/// predictions against the *sealed* global — survivor blocks always
/// cover all data exactly once, and the finalized prediction differs
/// from fault-free only by float re-association of the component sums.
#[allow(clippy::too_many_arguments)]
pub fn try_run(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    rank: usize,
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> Result<FaultRun, MachinesLost> {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m);
    let u = xu.rows;
    let _obsv_span = crate::obsv::span("protocol.pICF")
        .with_u64("machines", m as u64)
        .with_u64("rank", rank as u64);
    let mut cluster = spec.cluster();
    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;
    let mut db: Vec<Vec<usize>> = d_blocks.to_vec();

    /// Adopters rebuild their merged local summary (pre- or post-seal:
    /// the local depends only on the machine's own columns).
    #[allow(clippy::too_many_arguments)]
    fn relocal(
        cluster: &mut Cluster,
        adopters: &[usize],
        db: &[Vec<usize>],
        slabs: &[Mat],
        locals: &mut [Option<IcfLocalSummary>],
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        y_mean: f64,
        xu: &Mat,
        backend: &dyn Backend,
    ) {
        for &a in adopters {
            locals[a] = Some(cluster.compute_on(a, || {
                let xm = xd.select_rows(&db[a]);
                let ym: Vec<f64> =
                    db[a].iter().map(|&i| y[i] - y_mean).collect();
                backend.icf_local(hyp, &xm, &ym, xu, &slabs[a])
            }));
        }
    }

    /// Adopters rebuild their merged predictive component against the
    /// sealed global summary.
    #[allow(clippy::too_many_arguments)]
    fn recomp(
        cluster: &mut Cluster,
        adopters: &[usize],
        db: &[Vec<usize>],
        locals: &[Option<IcfLocalSummary>],
        comps: &mut [Option<Prediction>],
        global: &IcfGlobalSummary,
        hyp: &SeArd,
        xd: &Mat,
        y: &[f64],
        y_mean: f64,
        xu: &Mat,
        backend: &dyn Backend,
    ) {
        for &a in adopters {
            comps[a] = Some(cluster.compute_on(a, || {
                let xm = xd.select_rows(&db[a]);
                let ym: Vec<f64> =
                    db[a].iter().map(|&i| y[i] - y_mean).collect();
                let l =
                    locals[a].as_ref().expect("adopter has a summary");
                backend.icf_predict(hyp, xu, &xm, &ym, &l.s_dot, global)
            }));
        }
    }

    // STEP 2: row-based parallel ICF (fault-aware).
    let (mut slabs, records) =
        parallel_icf_ft(hyp, xd, &mut db, rank, &mut cluster)?;
    let r = slabs[0].rows;
    cluster.phase("parallel_icf");

    // STEP 3: local summaries; deaths before or during the gather hand
    // columns to adopters, who recompute (the global is not yet sealed).
    let dead = cluster.take_deaths("icf_local");
    adopt_columns(&mut cluster, &dead, &mut db, &mut slabs, None,
                  &records, hyp, xd, r, "icf_local")?;
    let mut locals: Vec<Option<IcfLocalSummary>> =
        cluster.compute_alive(|mid| {
            let xm = xd.select_rows(&db[mid]);
            let ym: Vec<f64> =
                db[mid].iter().map(|&i| y[i] - y_mean).collect();
            backend.icf_local(hyp, &xm, &ym, xu, &slabs[mid])
        });
    loop {
        let failed =
            cluster.gather_to_master(f64_bytes(r * r + r * u + r));
        if failed.is_empty() {
            break;
        }
        for &dm in &failed {
            locals[dm] = None;
        }
        let adopters =
            adopt_columns(&mut cluster, &failed, &mut db, &mut slabs,
                          None, &records, hyp, xd, r, "icf_local")?;
        relocal(&mut cluster, &adopters, &db, &slabs, &mut locals, hyp,
                xd, y, y_mean, xu, backend);
    }
    cluster.phase("icf_local");

    // STEP 4: master builds + broadcasts the global summary. Deaths at
    // phase entry precede the seal, so adopters recompute their locals
    // and the sum below still covers every column exactly once.
    let dead = cluster.take_deaths("icf_global");
    if !dead.is_empty() {
        for &dm in &dead {
            locals[dm] = None;
        }
        let adopters =
            adopt_columns(&mut cluster, &dead, &mut db, &mut slabs,
                          None, &records, hyp, xd, r, "icf_global")?;
        relocal(&mut cluster, &adopters, &db, &slabs, &mut locals, hyp,
                xd, y, y_mean, xu, backend);
    }
    let root = cluster.master();
    let global: IcfGlobalSummary = cluster.compute_on(root, || {
        let mut sum_y = vec![0.0; r];
        let mut sum_s = Mat::zeros(r, u);
        let mut sum_phi = Mat::zeros(r, r);
        for l in locals.iter().filter_map(|o| o.as_ref()) {
            for i in 0..r {
                sum_y[i] += l.y_dot[i];
            }
            sum_s.add_assign(&l.s_dot);
            sum_phi.add_assign(&l.phi);
        }
        backend.icf_global(hyp, &sum_y, &sum_s, &sum_phi)
    });
    // the global is sealed from here on; broadcast-failure deaths only
    // move columns and recompute locals against them
    let failed = cluster.bcast_from_master(f64_bytes(r * u + r));
    if !failed.is_empty() {
        for &dm in &failed {
            locals[dm] = None;
        }
        let adopters =
            adopt_columns(&mut cluster, &failed, &mut db, &mut slabs,
                          None, &records, hyp, xd, r, "icf_global")?;
        relocal(&mut cluster, &adopters, &db, &slabs, &mut locals, hyp,
                xd, y, y_mean, xu, backend);
    }
    cluster.phase("icf_global");

    // STEP 5: predictive components on alive machines.
    let dead = cluster.take_deaths("icf_components");
    if !dead.is_empty() {
        for &dm in &dead {
            locals[dm] = None;
        }
        let adopters =
            adopt_columns(&mut cluster, &dead, &mut db, &mut slabs,
                          None, &records, hyp, xd, r, "icf_components")?;
        relocal(&mut cluster, &adopters, &db, &slabs, &mut locals, hyp,
                xd, y, y_mean, xu, backend);
    }
    let mut comps: Vec<Option<Prediction>> =
        cluster.compute_alive(|mid| {
            let xm = xd.select_rows(&db[mid]);
            let ym: Vec<f64> =
                db[mid].iter().map(|&i| y[i] - y_mean).collect();
            let l = locals[mid].as_ref().expect("alive has a summary");
            backend.icf_predict(hyp, xu, &xm, &ym, &l.s_dot, &global)
        });
    loop {
        let failed = cluster.gather_to_master(f64_bytes(2 * u));
        if failed.is_empty() {
            break;
        }
        for &dm in &failed {
            locals[dm] = None;
            comps[dm] = None;
        }
        let adopters =
            adopt_columns(&mut cluster, &failed, &mut db, &mut slabs,
                          None, &records, hyp, xd, r, "icf_components")?;
        relocal(&mut cluster, &adopters, &db, &slabs, &mut locals, hyp,
                xd, y, y_mean, xu, backend);
        recomp(&mut cluster, &adopters, &db, &locals, &mut comps,
               &global, hyp, xd, y, y_mean, xu, backend);
    }
    cluster.phase("icf_components");

    // STEP 6: deaths at finalize entry lose a component contribution —
    // the adopter re-derives it before the master sums.
    let dead = cluster.take_deaths("finalize");
    if !dead.is_empty() {
        for &dm in &dead {
            locals[dm] = None;
            comps[dm] = None;
        }
        let adopters =
            adopt_columns(&mut cluster, &dead, &mut db, &mut slabs,
                          None, &records, hyp, xd, r, "finalize")?;
        relocal(&mut cluster, &adopters, &db, &slabs, &mut locals, hyp,
                xd, y, y_mean, xu, backend);
        recomp(&mut cluster, &adopters, &db, &locals, &mut comps,
               &global, hyp, xd, y, y_mean, xu, backend);
    }
    let root = cluster.master();
    let mut prediction = cluster.compute_on(root, || {
        let refs: Vec<&Prediction> =
            comps.iter().filter_map(|o| o.as_ref()).collect();
        crate::gp::summaries::icf_finalize(hyp, u, &refs)
    });
    prediction.shift_mean(y_mean);
    cluster.phase("finalize");

    let survivors = cluster.alive_ids();
    Ok(FaultRun {
        output: ProtocolOutput { prediction, metrics: cluster.finish() },
        d_blocks: db,
        u_blocks: vec![Vec::new(); m],
        survivors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::gp::icf_gp::{GramSource, IcfGp};
    use crate::linalg::{icf, matmul_tn};
    use crate::runtime::NativeBackend;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// The distributed row-based ICF produces exactly the serial pivoted
    /// ICF factor (same pivots, same values, just stored column-blocked).
    #[test]
    fn parallel_icf_matches_serial() {
        prop_check("picf-icf-match", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let d_blocks = random_partition(n, m, g.rng());

            let mut cluster = Cluster::new(m, crate::cluster::NetworkModel::instant());
            let slabs = parallel_icf(&hyp, &xd, &d_blocks, rank, &mut cluster);

            let serial = icf(&GramSource { hyp: &hyp, x: &xd }, rank, 0.0);
            // reassemble the distributed factor into global column order
            let r = serial.f.rows.max(slabs[0].rows);
            let mut f = Mat::zeros(r, n);
            for (mid, blk) in d_blocks.iter().enumerate() {
                for (c, &gi) in blk.iter().enumerate() {
                    for t in 0..slabs[mid].rows.min(r) {
                        f[(t, gi)] = slabs[mid][(t, c)];
                    }
                }
            }
            // compare the induced approximations (pivot ties may break
            // differently, but the pivoted factor is unique given pivots;
            // compare FᵀF instead of F to be order-robust)
            let approx_par = matmul_tn(&f, &f);
            let fpad = if serial.f.rows < r {
                let mut p = Mat::zeros(r, n);
                for t in 0..serial.f.rows {
                    p.row_mut(t).copy_from_slice(serial.f.row(t));
                }
                p
            } else {
                serial.f.clone()
            };
            let approx_ser = matmul_tn(&fpad, &fpad);
            assert!(approx_par.max_abs_diff(&approx_ser) < 1e-7,
                    "n={n} m={m} rank={rank}");
        });
    }

    /// THEOREM 3, protocol side: the distributed run equals the
    /// centralized ICF-based GP with the same rank.
    #[test]
    fn theorem3_picf_equals_centralized() {
        prop_check("thm3-protocol", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = g.usize_in(1, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());

            let out = run(&hyp, &xd, &y, &xu, &d_blocks, rank,
                          &NativeBackend, &ClusterSpec::new(m));
            let centralized = IcfGp::fit(&hyp, &xd, &y, rank, &d_blocks);
            let want = centralized.predict(&xu);
            assert_all_close(&out.prediction.mean, &want.mean, 1e-8, 1e-8);
            assert_all_close(&out.prediction.var, &want.var, 1e-8, 1e-8);
        });
    }

    /// Traffic grows with rank (Table 1: O((R² + R|U|) log M)).
    #[test]
    fn traffic_scales_with_rank() {
        let mut rng = crate::util::Pcg64::seed(4);
        let (n, u, m, d) = (24, 6, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let lo = run(&hyp, &xd, &y, &xu, &d_blocks, 4, &NativeBackend,
                     &ClusterSpec::new(m));
        let hi = run(&hyp, &xd, &y, &xu, &d_blocks, 16, &NativeBackend,
                     &ClusterSpec::new(m));
        assert!(hi.metrics.bytes_sent > lo.metrics.bytes_sent);
        assert!(hi.metrics.messages > lo.metrics.messages);
    }

    /// Zero-fault fault-aware factorization is bitwise the plain one,
    /// and every factor column can be rebuilt bitwise from the pivot
    /// records alone (the property column adoption relies on).
    #[test]
    fn ft_factor_bitwise_and_rebuildable() {
        prop_check("picf-ft-bitwise", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let d_blocks = random_partition(n, m, g.rng());

            let net = crate::cluster::NetworkModel::instant;
            let mut plain_c = Cluster::new(m, net());
            let plain = parallel_icf(&hyp, &xd, &d_blocks, rank,
                                     &mut plain_c);
            let spec = ClusterSpec {
                machines: m,
                net: net(),
                exec: crate::cluster::ParallelExecutor::serial(),
                faults: Some(crate::cluster::FaultPlan::none()),
            };
            let mut db = d_blocks.to_vec();
            let (slabs, records) =
                parallel_icf_ft(&hyp, &xd, &mut db, rank,
                                &mut spec.cluster())
                    .expect("no faults");
            assert_eq!(db, d_blocks);
            for mid in 0..m {
                assert_eq!(plain[mid].data.len(), slabs[mid].data.len());
                for (a, b) in
                    plain[mid].data.iter().zip(slabs[mid].data.iter())
                {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
                for (c, &gi) in d_blocks[mid].iter().enumerate() {
                    let (col, _) =
                        rebuilt_column(&hyp, &xd, &records, gi);
                    for (t, &v) in col.iter().enumerate() {
                        assert_eq!(v.to_bits(),
                                   slabs[mid][(t, c)].to_bits(),
                                   "column {gi} row {t}");
                    }
                }
            }
        });
    }

    /// Killing a machine at each pICF phase still completes with exact
    /// survivor coverage of all data rows.
    #[test]
    fn death_at_each_phase_completes() {
        let mut rng = crate::util::Pcg64::seed(11);
        let (n, u, m, d) = (20, 5, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        for phase in ["parallel_icf", "icf_local", "icf_global",
                      "icf_components", "finalize"] {
            let spec = ClusterSpec::new(m).with_faults(
                crate::cluster::FaultPlan::none().kill(1, phase));
            let fr = try_run(&hyp, &xd, &y, &xu, &d_blocks, 6,
                             &NativeBackend, &spec)
                .unwrap_or_else(|e| panic!("{phase}: {e}"));
            assert!(fr.d_blocks[1].is_empty(), "{phase}");
            assert_eq!(fr.survivors, vec![0, 2, 3], "{phase}");
            let mut covered: Vec<usize> =
                fr.d_blocks.iter().flatten().copied().collect();
            covered.sort_unstable();
            assert_eq!(covered, (0..n).collect::<Vec<_>>(), "{phase}");
            assert_eq!(fr.output.prediction.len(), u);
            assert!(fr.output.prediction.mean.iter()
                        .all(|v| v.is_finite()), "{phase}");
            assert!(fr.output.metrics.faults.deaths == 1, "{phase}");
            assert!(fr.output.metrics.faults.rebalances >= 1, "{phase}");
        }
    }

    /// Phases present in protocol order.
    #[test]
    fn phases_in_order() {
        let mut rng = crate::util::Pcg64::seed(6);
        let (n, u, m, d) = (12, 3, 3, 1);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n));
        let xu = Mat::from_vec(u, d, rng.normals(u));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let out = run(&hyp, &xd, &y, &xu, &d_blocks, 6, &NativeBackend,
                      &ClusterSpec::new(m));
        let names: Vec<&str> =
            out.metrics.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["parallel_icf", "icf_local", "icf_global",
                               "icf_components", "finalize"]);
    }
}
