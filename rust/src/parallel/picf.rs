//! pICF-based GP — Section 4, Steps 1–6, over the simulated cluster,
//! including the **row-based parallel ICF** of Chang et al. (2007):
//! machine m owns column block D_m of the factor; every iteration
//! all-reduces the pivot choice, broadcasts the pivot input and the
//! pivot's factor-column prefix, and each machine updates its slab —
//! O(R²·log M) communication, matching Table 1.

use super::{f64_bytes, ClusterSpec, ProtocolOutput};
use crate::cluster::mpi::MASTER;
use crate::cluster::Cluster;
use crate::gp::summaries::{IcfGlobalSummary, IcfLocalSummary};
use crate::gp::Prediction;
use crate::kernel::SeArd;
use crate::linalg::Mat;
use crate::runtime::Backend;

/// Distributed row-based parallel ICF (Step 2).
///
/// Returns machine m's slab `F_m ∈ R^{R×|D_m|}` of the factor of the
/// *noise-free* K_DD, where columns follow `d_blocks[m]` order. The
/// communication per iteration k is: one allreduce of the (value, owner)
/// pivot candidate + one broadcast of the pivot's input row (d floats)
/// and factor prefix (k floats).
pub fn parallel_icf(
    hyp: &SeArd,
    xd: &Mat,
    d_blocks: &[Vec<usize>],
    rank: usize,
    cluster: &mut Cluster,
) -> Vec<Mat> {
    let d = xd.cols;
    let rank = rank.min(xd.rows);

    // per-machine state: residual diagonals + slab rows built so far
    let mut resid: Vec<Vec<f64>> =
        d_blocks.iter().map(|b| vec![hyp.sf2(); b.len()]).collect();
    let mut slabs: Vec<Mat> =
        d_blocks.iter().map(|b| Mat::zeros(rank, b.len())).collect();

    for k in 0..rank {
        // (a) local pivot candidates — measured per machine. Ties break
        // toward the smallest *global* index, matching linalg::icf so
        // the distributed factor is bit-identical to the serial one.
        // Inline even under a thread-backed executor: this scan is a
        // microsecond-scale fold issued `rank` times, where pool
        // dispatch would cost more than the work (the heavy step (d)
        // slab update below does fan out).
        let candidates: Vec<(f64, usize)> = cluster.compute_all_inline(|mid| {
            let blk = &d_blocks[mid];
            resid[mid]
                .iter()
                .enumerate()
                .fold((f64::NEG_INFINITY, 0usize), |acc, (i, &v)| {
                    let better = v > acc.0
                        || (v == acc.0 && blk[i] < blk[acc.1]);
                    if better { (v, i) } else { acc }
                })
        });
        // (b) allreduce MAXLOC of the (value, owner) candidate — one
        // butterfly collective (MPI_Allreduce), 16 bytes
        cluster.allreduce(16);
        let (owner, local_i) = candidates.iter().enumerate().fold(
            (0usize, candidates[0].1),
            |(bm, bi), (mid, &(v, i))| {
                let (bv, bg) = (candidates[bm].0, d_blocks[bm][bi]);
                let better = v > bv || (v == bv && d_blocks[mid][i] < bg);
                if better { (mid, i) } else { (bm, bi) }
            },
        );
        let pivot_global = d_blocks[owner][local_i];
        let piv_val = candidates[owner].0;
        if piv_val <= 0.0 {
            break; // numerically exhausted — slabs keep zero rows
        }
        let piv = piv_val.sqrt();

        // (c) owner broadcasts x_pivot (d floats) + its factor prefix
        // F[0..k, pivot] (k floats)
        let prefix: Vec<f64> =
            (0..k).map(|t| slabs[owner][(t, local_i)]).collect();
        cluster.bcast_from_master(f64_bytes(d + k));

        // (d) every machine updates its slab row k — measured
        let x_piv: Vec<f64> = xd.row(pivot_global).to_vec();
        let mut updates: Vec<Vec<f64>> = cluster.compute_all(|mid| {
            let blk = &d_blocks[mid];
            let slab = &slabs[mid];
            let mut row = vec![0.0; blk.len()];
            for (c, &gi) in blk.iter().enumerate() {
                let mut v = hyp.k(&x_piv, xd.row(gi));
                for (t, &pf) in prefix.iter().enumerate() {
                    v -= pf * slab[(t, c)];
                }
                row[c] = v / piv;
            }
            row
        });
        // pin the pivot entry to piv exactly (mirrors linalg::icf — keeps
        // the residual streams of serial and distributed runs bitwise
        // identical so they stop at the same step)
        updates[owner][local_i] = piv;
        for (mid, row) in updates.into_iter().enumerate() {
            for (c, v) in row.into_iter().enumerate() {
                slabs[mid][(k, c)] = v;
                resid[mid][c] -= slabs[mid][(k, c)] * slabs[mid][(k, c)];
            }
        }
        // pivot column residual is exactly zero
        resid[owner][local_i] = 0.0;
    }
    slabs
}

/// Run the full pICF-based GP protocol (Steps 2–6).
#[allow(clippy::too_many_arguments)]
pub fn run(
    hyp: &SeArd,
    xd: &Mat,
    y: &[f64],
    xu: &Mat,
    d_blocks: &[Vec<usize>],
    rank: usize,
    backend: &dyn Backend,
    spec: &ClusterSpec,
) -> ProtocolOutput {
    let m = spec.machines;
    assert_eq!(d_blocks.len(), m);
    let u = xu.rows;
    let mut cluster = spec.cluster();
    let y_mean = y.iter().sum::<f64>() / y.len().max(1) as f64;

    // STEP 2: row-based parallel ICF.
    let slabs = parallel_icf(hyp, xd, d_blocks, rank, &mut cluster);
    let r = slabs[0].rows;
    cluster.phase("parallel_icf");

    // STEP 3: local summaries.
    let locals: Vec<IcfLocalSummary> = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.icf_local(hyp, &xm, &ym, xu, &slabs[mid])
    });
    // gather to master: (R² + R·U + R) doubles per machine
    cluster.gather_to_master(f64_bytes(r * r + r * u + r));
    cluster.phase("icf_local");

    // STEP 4: master builds + broadcasts the global summary.
    let global: IcfGlobalSummary = cluster.compute_on(MASTER, || {
        let mut sum_y = vec![0.0; r];
        let mut sum_s = Mat::zeros(r, u);
        let mut sum_phi = Mat::zeros(r, r);
        for l in &locals {
            for i in 0..r {
                sum_y[i] += l.y_dot[i];
            }
            sum_s.add_assign(&l.s_dot);
            sum_phi.add_assign(&l.phi);
        }
        backend.icf_global(hyp, &sum_y, &sum_s, &sum_phi)
    });
    cluster.bcast_from_master(f64_bytes(r * u + r));
    cluster.phase("icf_global");

    // STEP 5: predictive components.
    let comps: Vec<Prediction> = cluster.compute_all(|mid| {
        let xm = xd.select_rows(&d_blocks[mid]);
        let ym: Vec<f64> =
            d_blocks[mid].iter().map(|&i| y[i] - y_mean).collect();
        backend.icf_predict(hyp, xu, &xm, &ym, &locals[mid].s_dot, &global)
    });
    cluster.gather_to_master(f64_bytes(2 * u));
    cluster.phase("icf_components");

    // STEP 6: master finalizes.
    let mut prediction = cluster.compute_on(MASTER, || {
        let refs: Vec<&Prediction> = comps.iter().collect();
        crate::gp::summaries::icf_finalize(hyp, u, &refs)
    });
    prediction.shift_mean(y_mean);
    cluster.phase("finalize");

    ProtocolOutput { prediction, metrics: cluster.finish() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::random_partition;
    use crate::gp::icf_gp::{GramSource, IcfGp};
    use crate::linalg::{icf, matmul_tn};
    use crate::runtime::NativeBackend;
    use crate::testkit::prop::{prop_check, Gen};
    use crate::testkit::assert_all_close;

    fn rand_hyp(g: &mut Gen, d: usize) -> SeArd {
        SeArd {
            log_ls: g.uniform_vec(d, -0.3, 0.5),
            log_sf2: g.f64_in(-0.5, 0.5),
            log_sn2: g.f64_in(-3.0, -1.5),
        }
    }

    /// The distributed row-based ICF produces exactly the serial pivoted
    /// ICF factor (same pivots, same values, just stored column-blocked).
    #[test]
    fn parallel_icf_matches_serial() {
        prop_check("picf-icf-match", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let d_blocks = random_partition(n, m, g.rng());

            let mut cluster = Cluster::new(m, crate::cluster::NetworkModel::instant());
            let slabs = parallel_icf(&hyp, &xd, &d_blocks, rank, &mut cluster);

            let serial = icf(&GramSource { hyp: &hyp, x: &xd }, rank, 0.0);
            // reassemble the distributed factor into global column order
            let r = serial.f.rows.max(slabs[0].rows);
            let mut f = Mat::zeros(r, n);
            for (mid, blk) in d_blocks.iter().enumerate() {
                for (c, &gi) in blk.iter().enumerate() {
                    for t in 0..slabs[mid].rows.min(r) {
                        f[(t, gi)] = slabs[mid][(t, c)];
                    }
                }
            }
            // compare the induced approximations (pivot ties may break
            // differently, but the pivoted factor is unique given pivots;
            // compare FᵀF instead of F to be order-robust)
            let approx_par = matmul_tn(&f, &f);
            let fpad = if serial.f.rows < r {
                let mut p = Mat::zeros(r, n);
                for t in 0..serial.f.rows {
                    p.row_mut(t).copy_from_slice(serial.f.row(t));
                }
                p
            } else {
                serial.f.clone()
            };
            let approx_ser = matmul_tn(&fpad, &fpad);
            assert!(approx_par.max_abs_diff(&approx_ser) < 1e-7,
                    "n={n} m={m} rank={rank}");
        });
    }

    /// THEOREM 3, protocol side: the distributed run equals the
    /// centralized ICF-based GP with the same rank.
    #[test]
    fn theorem3_picf_equals_centralized() {
        prop_check("thm3-protocol", 6, |g| {
            let d = g.usize_in(1, 3);
            let m = g.usize_in(1, 4);
            let n = m * g.usize_in(2, 5);
            let u = g.usize_in(1, 5);
            let rank = g.usize_in(1, n + 1).min(n);
            let hyp = rand_hyp(g, d);
            let xd = Mat::from_vec(n, d, g.uniform_vec(n * d, -2.0, 2.0));
            let xu = Mat::from_vec(u, d, g.uniform_vec(u * d, -2.0, 2.0));
            let y = g.normal_vec(n);
            let d_blocks = random_partition(n, m, g.rng());

            let out = run(&hyp, &xd, &y, &xu, &d_blocks, rank,
                          &NativeBackend, &ClusterSpec::new(m));
            let centralized = IcfGp::fit(&hyp, &xd, &y, rank, &d_blocks);
            let want = centralized.predict(&xu);
            assert_all_close(&out.prediction.mean, &want.mean, 1e-8, 1e-8);
            assert_all_close(&out.prediction.var, &want.var, 1e-8, 1e-8);
        });
    }

    /// Traffic grows with rank (Table 1: O((R² + R|U|) log M)).
    #[test]
    fn traffic_scales_with_rank() {
        let mut rng = crate::util::Pcg64::seed(4);
        let (n, u, m, d) = (24, 6, 4, 2);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n * d));
        let xu = Mat::from_vec(u, d, rng.normals(u * d));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let lo = run(&hyp, &xd, &y, &xu, &d_blocks, 4, &NativeBackend,
                     &ClusterSpec::new(m));
        let hi = run(&hyp, &xd, &y, &xu, &d_blocks, 16, &NativeBackend,
                     &ClusterSpec::new(m));
        assert!(hi.metrics.bytes_sent > lo.metrics.bytes_sent);
        assert!(hi.metrics.messages > lo.metrics.messages);
    }

    /// Phases present in protocol order.
    #[test]
    fn phases_in_order() {
        let mut rng = crate::util::Pcg64::seed(6);
        let (n, u, m, d) = (12, 3, 3, 1);
        let hyp = SeArd::isotropic(d, 1.0, 1.0, 0.1);
        let xd = Mat::from_vec(n, d, rng.normals(n));
        let xu = Mat::from_vec(u, d, rng.normals(u));
        let y = rng.normals(n);
        let d_blocks = random_partition(n, m, &mut rng);
        let out = run(&hyp, &xd, &y, &xu, &d_blocks, 6, &NativeBackend,
                      &ClusterSpec::new(m));
        let names: Vec<&str> =
            out.metrics.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["parallel_icf", "icf_local", "icf_global",
                               "icf_components", "finalize"]);
    }
}
