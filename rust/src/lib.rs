//! # pgpr — Parallel Gaussian Process Regression
//!
//! A reproduction of Chen et al., *Parallel Gaussian Process Regression
//! with Low-Rank Covariance Matrix Approximations* (UAI 2013), as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   pPITC / pPIC / pICF-based-GP protocols ([`parallel`]) over a
//!   discrete-event cluster ([`cluster`]), their centralized counterparts
//!   and the exact FGP baseline ([`gp`]), plus a real-time prediction
//!   server ([`server`]) and distributed PITC marginal-likelihood
//!   training ([`train`]) on the same cluster topology — all constructed
//!   and driven through the unified [`api`] facade (`Gp::builder()`,
//!   one `Regressor` trait, method choice as a runtime value).
//! * **L2/L1 (python, build-time only)** — the GP algebra and the Pallas
//!   SE-Gram kernel, AOT-lowered to HLO text artifacts executed through
//!   [`runtime`] (PJRT via the `xla` crate, behind the `pjrt` cargo
//!   feature). Python never runs on the request path.
//!
//! ## Why the protocols are exact (Theorems 1–3)
//!
//! pPITC, pPIC and the pICF-based GP are *reformulations*, not new
//! approximations: each machine condenses its data block into a local
//! summary (Definition 2), summaries add up into a global summary
//! (Definition 3), and predictions from that summary equal what the
//! centralized PITC / PIC / ICF-based GP would produce on the same
//! partition (Theorems 1–3). The test suite treats those identities as
//! a hard oracle, including across *execution modes*: running the
//! simulated machines truly concurrently on a
//! [`cluster::ParallelExecutor`] thread pool must (and does) reproduce
//! the serial run to ≤1e-10.
//!
//! ## Execution model
//!
//! The [`cluster`] simulator charges each virtual node the measured
//! wall time of its own work and models communication with
//! `O(log M)`-round collectives, producing the paper's *incurred time*
//! (makespan). Independently, the host can execute node work serially
//! or on real threads (`--parallel-threads` in the CLI,
//! [`parallel::ClusterSpec::with_threads`] in code); reports carry both
//! the modeled makespan and the realized wall clock
//! ([`cluster::RunMetrics::wall_s`]).
//!
//! Substrates built from scratch (offline environment; see DESIGN.md):
//! dense linear algebra ([`linalg`]), covariance functions ([`kernel`]),
//! synthetic AIMPEAK/SARCOS workloads ([`data`]), a thread pool, JSON,
//! PRNG ([`util`]), a property-testing mini-framework ([`testkit`]), a
//! micro-benchmark harness ([`bench_support`]), a telemetry layer
//! ([`obsv`]: metrics registry, phase-span tracing, JSON/Prometheus
//! exporters — `pgpr stats`) and a CLI ([`cli`]). The serving stack is
//! additionally exposed over real TCP sockets by [`net`] (`pgpr node` /
//! `pgpr loadgen`): a hardened std-only HTTP/1.1 front-end with
//! admission control, backpressure and an open-loop load harness.
//! Fitted models outlive their process through [`store`]: versioned,
//! checksummed checkpoints for every method (plus `OnlineGp` stream
//! state), crash-safe snapshots, cold-start and atomic hot-swap.

pub mod api;
pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod data;
pub mod gp;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod obsv;
pub mod parallel;
pub mod runtime;
pub mod server;
pub mod store;
pub mod testkit;
pub mod train;
pub mod util;

/// Crate version (kept in sync with Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
