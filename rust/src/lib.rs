//! # pgpr — Parallel Gaussian Process Regression
//!
//! A reproduction of Chen et al., *Parallel Gaussian Process Regression
//! with Low-Rank Covariance Matrix Approximations* (UAI 2013), as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   pPITC / pPIC / pICF-based-GP protocols ([`parallel`]) over a
//!   discrete-event cluster ([`cluster`]), their centralized counterparts
//!   and the exact FGP baseline ([`gp`]), plus a real-time prediction
//!   server ([`server`]).
//! * **L2/L1 (python, build-time only)** — the GP algebra and the Pallas
//!   SE-Gram kernel, AOT-lowered to HLO text artifacts executed through
//!   [`runtime`] (PJRT via the `xla` crate). Python never runs on the
//!   request path.
//!
//! Substrates built from scratch (offline environment; see DESIGN.md):
//! dense linear algebra ([`linalg`]), covariance functions ([`kernel`]),
//! synthetic AIMPEAK/SARCOS workloads ([`data`]), a thread pool, JSON,
//! PRNG ([`util`]), a property-testing mini-framework ([`testkit`]), a
//! micro-benchmark harness ([`bench_support`]) and a CLI ([`cli`]).

pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod data;
pub mod gp;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod parallel;
pub mod runtime;
pub mod server;
pub mod testkit;
pub mod util;

/// Crate version (kept in sync with Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
