//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment vendors no external registry, so this tiny
//! path-dependency provides the slice of anyhow's API the crate actually
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros, and the
//! [`Context`] extension trait. Errors are stored as a flat chain of
//! messages: `Display` prints the outermost message (matching anyhow),
//! `{:#}` and `Debug` print the whole `outer: inner: ...` chain.
//!
//! Swap this for the real `anyhow` by pointing the dependency back at
//! crates.io; no call site changes are needed.

use std::fmt;

/// `Result<T, Error>` with the error type defaulted, like anyhow's.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: `chain[0]` is the outermost context message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a context message (what `Context` methods call).
    pub fn wrap(mut self, ctx: impl fmt::Display) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// The `outer: inner: ...` rendering of the whole chain.
    pub fn full_chain(&self) -> String {
        self.chain.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full cause chain, as in anyhow
            write!(f, "{}", self.full_chain())
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.full_chain())
    }
}

// Like anyhow: any std error converts via `?`. `Error` itself does NOT
// implement `std::error::Error`, which is what keeps this blanket impl
// coherent alongside the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold,
/// like anyhow's `ensure!` (message form and bare-condition form).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(
                concat!("condition failed: ", stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(e.to_string(), "bad thing 7");
        assert_eq!(format!("{e:#}"), "bad thing 7");
    }

    #[test]
    fn bail_returns() {
        fn f() -> Result<()> {
            bail!("nope {}", "x");
        }
        assert_eq!(f().unwrap_err().to_string(), "nope x");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_chains() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert!(format!("{e:#}").starts_with("reading manifest: "));
    }

    #[test]
    fn option_context() {
        let e = None::<u8>.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }
}
