"""pytest bootstrap: make `compile` importable and force x64."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

import compile  # noqa: F401  (enables jax x64 at import)
