"""AOT pipeline checks: manifest integrity + HLO text hygiene.

The rust runtime trusts `artifacts/manifest.json` blindly, so this file is
the gate: every graph must lower, contain no LAPACK/CUDA custom-calls
(unresolvable in xla_extension 0.5.1), and declare shapes consistent with
the model registry.
"""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, ["tiny"])
    return out, manifest


def test_manifest_structure(built):
    out, manifest = built
    assert manifest["format"] == "hlo-text"
    assert manifest["dtype"] == "float64"
    tiny = manifest["profiles"]["tiny"]
    assert set(tiny["graphs"]) == set(model.GRAPHS)
    for g in tiny["graphs"].values():
        assert os.path.exists(os.path.join(out, g["file"]))
        assert g["outputs"] >= 2
        assert all(len(i) == 3 for i in g["inputs"])


def test_manifest_json_roundtrip(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest


def test_hlo_text_is_parseable_entrypoint(built):
    out, manifest = built
    for g in manifest["profiles"]["tiny"]["graphs"].values():
        with open(os.path.join(out, g["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule"), g["file"]
        assert "ENTRY" in text, g["file"]


def test_no_forbidden_custom_calls(built):
    out, manifest = built
    for g in manifest["profiles"]["tiny"]["graphs"].values():
        with open(os.path.join(out, g["file"])) as f:
            text = f.read().replace(" ", "")
        for bad in aot.FORBIDDEN_CALL_PREFIXES:
            assert f'custom_call_target="{bad}' not in text, g["file"]


def test_input_shapes_match_registry(built):
    _, manifest = built
    profile = aot.PROFILES["tiny"]
    for name, g in manifest["profiles"]["tiny"]["graphs"].items():
        _, shapes = model.GRAPHS[name]
        want = [list(s.shape) for s in shapes(profile)]
        got = [i[1] for i in g["inputs"]]
        assert got == want, name


def test_deterministic_lowering(built):
    """Re-lowering the same graph yields the same HLO text (sha match)."""
    _, manifest = built
    text, _, _ = aot.lower_graph("icf_local", aot.PROFILES["tiny"])
    import hashlib
    sha = hashlib.sha256(text.encode()).hexdigest()[:16]
    assert sha == manifest["profiles"]["tiny"]["graphs"]["icf_local"]["sha256"]


def test_unknown_profile_rejected():
    import subprocess, sys
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--profiles", "nope",
         "--out-dir", "/tmp/_aot_nope"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True)
    assert r.returncode != 0
