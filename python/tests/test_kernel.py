"""L1 correctness: Pallas SE-Gram kernel vs the pure-jnp oracle.

This is the core kernel-level correctness signal: hypothesis sweeps
shapes, dtypes, tile choices and hyperparameters; every case must match
``ref.py`` to tight tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.se_gram import se_gram, se_gram_scaled, pick_tile
from compile.kernels import ref


def _rand(rng, *shape, dtype=np.float64):
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- pick_tile

@given(n=st.integers(1, 4096), target=st.integers(1, 256))
def test_pick_tile_divides_and_bounded(n, target):
    t = pick_tile(n, target)
    assert 1 <= t <= min(n, target)
    assert n % t == 0


def test_pick_tile_prefers_large():
    assert pick_tile(256) == 128
    assert pick_tile(100) == 100
    assert pick_tile(200, 128) == 100
    assert pick_tile(7, 4) == 1


def test_pick_tile_rejects_nonpositive():
    with pytest.raises(ValueError):
        pick_tile(0)


# ------------------------------------------------------------ kernel vs ref

@settings(max_examples=25, deadline=None)
@given(
    n1=st.integers(1, 40),
    n2=st.integers(1, 40),
    d=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_se_gram_scaled_matches_ref(n1, n2, d, seed):
    rng = np.random.default_rng(seed)
    x1, x2 = _rand(rng, n1, d), _rand(rng, n2, d)
    got = se_gram_scaled(x1, x2)
    want = ref.se_gram_scaled_ref(x1, x2)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    n1=st.integers(1, 32),
    n2=st.integers(1, 32),
    d=st.integers(1, 6),
    log_sf2=st.floats(-2.0, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_se_gram_full_matches_ref(n1, n2, d, log_sf2, seed):
    rng = np.random.default_rng(seed)
    x1, x2 = _rand(rng, n1, d), _rand(rng, n2, d)
    log_ls = jnp.asarray(rng.uniform(-1.0, 1.0, d))
    got = se_gram(x1, x2, log_ls, log_sf2)
    want = ref.se_gram_ref(x1, x2, log_ls, log_sf2)
    np.testing.assert_allclose(got, want, rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("tile1,tile2", [(1, 1), (2, 8), (8, 2), (16, 16)])
def test_se_gram_tile_invariance(tile1, tile2):
    """The tiling schedule must not change the numbers."""
    rng = np.random.default_rng(7)
    x1, x2 = _rand(rng, 16, 5), _rand(rng, 16, 5)
    base = ref.se_gram_scaled_ref(x1, x2)
    got = se_gram_scaled(x1, x2, tile1=tile1, tile2=tile2)
    np.testing.assert_allclose(got, base, rtol=1e-12, atol=1e-12)


def test_se_gram_rejects_nondividing_tiles():
    rng = np.random.default_rng(0)
    x = _rand(rng, 10, 3)
    with pytest.raises(ValueError):
        se_gram_scaled(x, x, tile1=3, tile2=5)


def test_se_gram_rejects_dim_mismatch():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        se_gram_scaled(_rand(rng, 4, 3), _rand(rng, 4, 2))


def test_se_gram_f32():
    """f32 path (looser tolerance; artifacts themselves are f64)."""
    rng = np.random.default_rng(3)
    x1 = _rand(rng, 12, 4, dtype=np.float32)
    x2 = _rand(rng, 20, 4, dtype=np.float32)
    got = se_gram_scaled(x1, x2)
    assert got.dtype == jnp.float32
    want = ref.se_gram_scaled_ref(x1, x2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_se_gram_diagonal_is_unit():
    """k(x, x) == 1 for the scaled kernel (before sf2)."""
    rng = np.random.default_rng(11)
    x = _rand(rng, 24, 5)
    k = se_gram_scaled(x, x)
    np.testing.assert_allclose(np.diag(k), np.ones(24), rtol=0, atol=1e-12)


def test_se_gram_symmetry():
    rng = np.random.default_rng(13)
    x = _rand(rng, 18, 4)
    k = np.asarray(se_gram_scaled(x, x))
    np.testing.assert_allclose(k, k.T, rtol=0, atol=1e-12)


def test_se_gram_bounded():
    """0 < k <= 1 always (positive-definite SE kernel values)."""
    rng = np.random.default_rng(17)
    k = np.asarray(se_gram_scaled(_rand(rng, 30, 6), _rand(rng, 25, 6)))
    assert (k > 0).all() and (k <= 1.0 + 1e-15).all()


def test_se_gram_lengthscale_monotone():
    """Longer length-scales => higher correlation, pointwise."""
    rng = np.random.default_rng(19)
    x1, x2 = _rand(rng, 10, 3), _rand(rng, 10, 3)
    short = np.asarray(se_gram(x1, x2, jnp.full(3, -1.0), 0.0))
    longer = np.asarray(se_gram(x1, x2, jnp.full(3, 1.0), 0.0))
    assert (longer >= short - 1e-15).all()


def test_se_cov_full_ref_noise_on_diagonal():
    rng = np.random.default_rng(23)
    x = _rand(rng, 9, 3)
    hyp = (jnp.zeros(3), 0.5, -2.0)
    k_plain = ref.se_gram_ref(x, x, hyp[0], hyp[1])
    k_noise = ref.se_cov_full_ref(x, x, hyp[0], hyp[1], hyp[2], same=True)
    np.testing.assert_allclose(
        np.asarray(k_noise - k_plain),
        np.exp(-2.0) * np.eye(9), rtol=1e-12, atol=1e-12)
