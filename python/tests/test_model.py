"""L2 correctness: model graphs vs direct numpy oracles.

Three levels:
  1. the pure-HLO linalg helpers (chol / solves) vs numpy.linalg;
  2. each graph vs a literal numpy transcription of its equations;
  3. *assembly* tests — running the per-machine graphs and combining them
     exactly like the rust coordinator must reproduce the centralized
     PITC (Thm 1), PIC (Thm 2) and ICF (Thm 3) formulas computed directly
     in numpy.  These are the paper's equivalence theorems, executable.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng


def hyp_vec(d, log_ls=0.0, log_sf2=0.3, log_sn2=-2.0):
    return jnp.asarray([log_ls] * d + [log_sf2, log_sn2])


def np_cov(x1, x2, hyp, same, jitter=False):
    """numpy transcription of model.cov (incl. noise + jitter policy)."""
    d = x1.shape[1]
    k = np.asarray(ref.se_gram_ref(jnp.asarray(x1), jnp.asarray(x2),
                                   jnp.asarray(hyp[:d]), hyp[d]))
    if same:
        bump = np.exp(hyp[d + 1])
        if jitter:
            bump += model.JITTER_SCALE * np.exp(hyp[d])
        k = k + bump * np.eye(len(x1))
    elif jitter:
        k = k + model.JITTER_SCALE * np.exp(hyp[d]) * np.eye(len(x1))
    return k


# ------------------------------------------------------------- linalg HLO

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_chol_matches_numpy(n, seed):
    rng = RNG(seed)
    a = rng.standard_normal((n, n))
    spd = a @ a.T + n * np.eye(n)
    got = np.asarray(model.chol(jnp.asarray(spd)))
    want = np.linalg.cholesky(spd)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 20), k=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
def test_solve_lower_and_upper(n, k, seed):
    rng = RNG(seed)
    a = rng.standard_normal((n, n))
    spd = a @ a.T + n * np.eye(n)
    l = np.linalg.cholesky(spd)
    b = rng.standard_normal((n, k))
    y = np.asarray(model.solve_lower(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(l @ y, b, rtol=1e-9, atol=1e-9)
    x = np.asarray(model.solve_upper_t(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(l.T @ x, b, rtol=1e-9, atol=1e-9)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 20), seed=st.integers(0, 2**31 - 1))
def test_cho_solve_vector(n, seed):
    rng = RNG(seed)
    a = rng.standard_normal((n, n))
    spd = a @ a.T + n * np.eye(n)
    l = np.linalg.cholesky(spd)
    b = rng.standard_normal(n)
    x = np.asarray(model.cho_solve(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(spd @ x, b, rtol=1e-8, atol=1e-8)


# --------------------------------------------------------- graph oracles

def make_problem(seed, n=24, m=3, s=6, u=7, d=3):
    rng = RNG(seed)
    xd = rng.uniform(-2, 2, (n, d))
    xs = rng.uniform(-2, 2, (s, d))
    xu = rng.uniform(-2, 2, (u, d))
    y = rng.standard_normal(n)
    hyp = np.asarray([0.2] * d + [0.3, -2.0])
    blocks = np.split(np.arange(n), m)
    return xd, xs, xu, y, hyp, blocks


def test_local_summary_matches_numpy():
    xd, xs, _, y, hyp, blocks = make_problem(0)
    b = blocks[0]
    xm, ym = xd[b], y[b]
    y_dot, s_dot, l_m = model.local_summary(
        jnp.asarray(xm), jnp.asarray(ym), jnp.asarray(xs), jnp.asarray(hyp))
    # numpy oracle — Definition 2
    k_ss = np_cov(xs, xs, hyp, same=True, jitter=True)
    k_ms = np_cov(xm, xs, hyp, same=False)
    q = k_ms @ np.linalg.solve(k_ss, k_ms.T)
    sig = np_cov(xm, xm, hyp, same=True, jitter=True) - q
    np.testing.assert_allclose(np.asarray(l_m) @ np.asarray(l_m).T, sig,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(np.asarray(y_dot),
                               k_ms.T @ np.linalg.solve(sig, ym),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(s_dot),
                               k_ms.T @ np.linalg.solve(sig, k_ms),
                               rtol=1e-8, atol=1e-8)


def _global_summary(xd, xs, y, hyp, blocks):
    """Assemble eqs. (5)-(6) from per-block graph calls."""
    s = len(xs)
    y_glob = np.zeros(s)
    s_glob = np_cov(xs, xs, hyp, same=True)  # Sigma_SS, paper-literal
    locals_ = []
    for b in blocks:
        y_dot, s_dot, l_m = model.local_summary(
            jnp.asarray(xd[b]), jnp.asarray(y[b]), jnp.asarray(xs),
            jnp.asarray(hyp))
        y_glob += np.asarray(y_dot)
        s_glob += np.asarray(s_dot)
        locals_.append((np.asarray(y_dot), np.asarray(s_dot),
                        np.asarray(l_m)))
    return y_glob, s_glob, locals_


def _pitc_direct(xd, xs, xu, y, hyp, blocks):
    """Centralized PITC — eqs. (9)-(11), literal numpy."""
    k_ss = np_cov(xs, xs, hyp, same=True, jitter=True)
    k_ds = np_cov(xd, xs, hyp, same=False)
    k_us = np_cov(xu, xs, hyp, same=False)
    kss_inv = np.linalg.inv(k_ss)
    gamma_dd = k_ds @ kss_inv @ k_ds.T
    gamma_ud = k_us @ kss_inv @ k_ds.T
    lam = np.zeros_like(gamma_dd)
    sig_dd = np_cov(xd, xd, hyp, same=True)
    for b in blocks:
        blk = np.ix_(b, b)
        lam[blk] = (sig_dd - gamma_dd)[blk]
        # jitter consistency with the graphs
        lam[blk] += model.JITTER_SCALE * np.exp(hyp[-2]) * np.eye(len(b))
    a = np.linalg.inv(gamma_dd + lam)
    mu = gamma_ud @ a @ y
    gamma_uu = k_us @ kss_inv @ k_us.T
    sig_uu_diag = np.full(len(xu), np.exp(hyp[-2]) + np.exp(hyp[-1]))
    var = sig_uu_diag - np.diag(gamma_ud @ a @ gamma_ud.T) \
        + (np.diag(gamma_uu) - np.diag(gamma_uu))
    return mu, var


def test_theorem1_ppitc_equals_pitc():
    xd, xs, xu, y, hyp, blocks = make_problem(1)
    y_glob, s_glob, _ = _global_summary(xd, xs, y, hyp, blocks)
    mu, var = model.ppitc_predict(
        jnp.asarray(xu), jnp.asarray(xs), jnp.asarray(y_glob),
        jnp.asarray(s_glob), jnp.asarray(hyp))
    mu_d, _ = _pitc_direct(xd, xs, xu, y, hyp, blocks)
    np.testing.assert_allclose(np.asarray(mu), mu_d, rtol=1e-6, atol=1e-6)


def test_ppitc_variance_formula():
    """Variance (8) directly: Sigma_uu - K_us (Kss^-1 - Sglob^-1) K_su."""
    xd, xs, xu, y, hyp, blocks = make_problem(2)
    y_glob, s_glob, _ = _global_summary(xd, xs, y, hyp, blocks)
    _, var = model.ppitc_predict(
        jnp.asarray(xu), jnp.asarray(xs), jnp.asarray(y_glob),
        jnp.asarray(s_glob), jnp.asarray(hyp))
    k_us = np_cov(xu, xs, hyp, same=False)
    k_ss = np_cov(xs, xs, hyp, same=True, jitter=True)
    sg = s_glob + model.JITTER_SCALE * np.eye(len(xs))
    prior = np.full(len(xu), np.exp(hyp[-2]) + np.exp(hyp[-1]))
    want = prior - np.diag(
        k_us @ (np.linalg.inv(k_ss) - np.linalg.inv(sg)) @ k_us.T)
    np.testing.assert_allclose(np.asarray(var), want, rtol=1e-7, atol=1e-8)
    assert (np.asarray(var) > 0).all()


def _pic_direct(xd, xs, xu, y, hyp, blocks):
    """Centralized PIC — eqs. (15)-(18), literal numpy."""
    k_ss = np_cov(xs, xs, hyp, same=True, jitter=True)
    kss_inv = np.linalg.inv(k_ss)
    k_ds = np_cov(xd, xs, hyp, same=False)
    k_us = np_cov(xu, xs, hyp, same=False)
    gamma_dd = k_ds @ kss_inv @ k_ds.T
    sig_dd = np_cov(xd, xd, hyp, same=True)
    lam = np.zeros_like(gamma_dd)
    for b in blocks:
        blk = np.ix_(b, b)
        lam[blk] = (sig_dd - gamma_dd)[blk]
        lam[blk] += model.JITTER_SCALE * np.exp(hyp[-2]) * np.eye(len(b))
    a = np.linalg.inv(gamma_dd + lam)
    # Gamma-tilde: exact cross-covariance on the "own" block (i = m maps
    # U_m to D_m); here we predict the whole U from machine 0's view is
    # *not* what PIC does — the assembly test below builds U_m per block.
    return k_ss, kss_inv, k_ds, k_us, a, lam


def test_theorem2_ppic_equals_pic():
    """Assemble pPIC per machine and compare to centralized PIC (15)-(16)."""
    xd, xs, xu, y, hyp, blocks = make_problem(3, n=24, m=3, u=9)
    u_blocks = np.split(np.arange(len(xu)), 3)
    y_glob, s_glob, locals_ = _global_summary(xd, xs, y, hyp, blocks)

    mu_p = np.zeros(len(xu))
    var_p = np.zeros(len(xu))
    for m, (b, ub) in enumerate(zip(blocks, u_blocks)):
        y_dot, s_dot, l_m = locals_[m]
        mu, var = model.ppic_predict(
            jnp.asarray(xu[ub]), jnp.asarray(xs), jnp.asarray(xd[b]),
            jnp.asarray(y[b]), jnp.asarray(l_m), jnp.asarray(y_dot),
            jnp.asarray(s_dot), jnp.asarray(y_glob), jnp.asarray(s_glob),
            jnp.asarray(hyp))
        mu_p[ub] = np.asarray(mu)
        var_p[ub] = np.asarray(var)

    # centralized PIC
    k_ss, kss_inv, k_ds, k_us, a, lam = _pic_direct(
        xd, xs, xu, y, hyp, blocks)
    gamma_ud = k_us @ kss_inv @ k_ds.T
    gt = gamma_ud.copy()
    for m, (b, ub) in enumerate(zip(blocks, u_blocks)):
        gt[np.ix_(ub, b)] = np_cov(xu[ub], xd[b], hyp, same=False)
    mu_c = gt @ a @ y
    prior = np.full(len(xu), np.exp(hyp[-2]) + np.exp(hyp[-1]))
    var_c = prior - np.diag(gt @ a @ gt.T)
    np.testing.assert_allclose(mu_p, mu_c, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(var_p, var_c, rtol=1e-5, atol=1e-6)


def test_theorem3_picf_equals_icf():
    """Assemble pICF from graphs; compare to (28)-(29) with random F."""
    xd, xs, xu, y, hyp, blocks = make_problem(4, n=24, m=3, u=7)
    rng = RNG(44)
    r = 10
    f = rng.standard_normal((r, len(xd))) * 0.4
    sn2 = np.exp(hyp[-1])

    sum_y = np.zeros(r)
    sum_s = np.zeros((r, len(xu)))
    sum_phi = np.zeros((r, r))
    for m, b in enumerate(blocks):
        y_dot, s_dot, phi_m = model.icf_local(
            jnp.asarray(xd[b]), jnp.asarray(y[b]), jnp.asarray(xu),
            jnp.asarray(f[:, b]), jnp.asarray(hyp))
        sum_y += np.asarray(y_dot)
        sum_s += np.asarray(s_dot)
        sum_phi += np.asarray(phi_m)
    # numpy check of the local pieces
    np.testing.assert_allclose(sum_y, f @ y, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(sum_phi, f @ f.T, rtol=1e-9, atol=1e-9)

    y_glob, s_glob = model.icf_global(
        jnp.asarray(sum_y), jnp.asarray(sum_s), jnp.asarray(sum_phi),
        jnp.asarray(hyp))

    mu = np.zeros(len(xu))
    var_sub = np.zeros(len(xu))
    for m, b in enumerate(blocks):
        s_dot_m = f[:, b] @ np_cov(xd[b], xu, hyp, same=False)
        mu_m, var_m = model.icf_predict(
            jnp.asarray(xu), jnp.asarray(xd[b]), jnp.asarray(y[b]),
            jnp.asarray(s_dot_m), y_glob, s_glob, jnp.asarray(hyp))
        mu += np.asarray(mu_m)
        var_sub += np.asarray(var_m)
    prior = np.full(len(xu), np.exp(hyp[-2]) + np.exp(hyp[-1]))
    var = prior - var_sub

    # centralized ICF — (28)-(29)
    k_ud = np_cov(xu, xd, hyp, same=False)
    ainv = np.linalg.inv(f.T @ f + sn2 * np.eye(len(xd)))
    mu_c = k_ud @ ainv @ y
    var_c = prior - np.diag(k_ud @ ainv @ k_ud.T)
    np.testing.assert_allclose(mu, mu_c, rtol=1e-7, atol=1e-8)
    np.testing.assert_allclose(var, var_c, rtol=1e-6, atol=1e-8)


def test_icf_global_solve():
    """(22)-(23): Phi * y_glob == sum_y."""
    rng = RNG(5)
    r, u = 8, 5
    hyp = np.asarray([0.0, 0.0, 0.3, -1.5])
    f = rng.standard_normal((r, 20))
    sum_phi = f @ f.T
    sum_y = rng.standard_normal(r)
    sum_s = rng.standard_normal((r, u))
    y_glob, s_glob = model.icf_global(
        jnp.asarray(sum_y), jnp.asarray(sum_s), jnp.asarray(sum_phi),
        jnp.asarray(hyp))
    phi = np.eye(r) + np.exp(1.5) * sum_phi
    np.testing.assert_allclose(phi @ np.asarray(y_glob), sum_y,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(phi @ np.asarray(s_glob), sum_s,
                               rtol=1e-9, atol=1e-9)


def test_cov_diag():
    x = jnp.asarray(RNG(6).standard_normal((5, 3)))
    hyp = hyp_vec(3)
    got = np.asarray(model.cov_diag(x, hyp))
    np.testing.assert_allclose(
        got, np.full(5, np.exp(0.3) + np.exp(-2.0)), rtol=1e-12)


def test_graph_registry_shapes():
    """Every registered graph traces at its manifest shapes."""
    import jax
    profile = {"d": 3, "block": 8, "support": 4, "pred_block": 6, "rank": 5}
    for name, (fn, shapes) in model.GRAPHS.items():
        out = jax.eval_shape(fn, *shapes(profile))
        assert len(out) >= 2, name
