"""AOT pipeline: lower every L2 graph to HLO *text* + write the manifest.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla_extension 0.5.1
runtime behind the rust `xla` crate rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly
(see /opt/xla-example/README.md).

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--profiles tiny,...]

Outputs, per profile P and graph G:
    artifacts/<P>_<G>.hlo.txt
plus a single ``artifacts/manifest.json`` describing every artifact's
input shapes (in call order) and output arity — the rust runtime loads
artifacts strictly through this manifest.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# Shape profiles pinned at AOT time.  The rust coordinator pads prediction
# batches up to ``pred_block`` (safe: per-row independent given summaries)
# and requires data blocks of exactly ``block`` rows (the paper's Def. 1
# even partition).  ``d`` counts input features; hyp vectors are d+2.
PROFILES = {
    # fast profile for unit/integration tests
    "tiny": {"d": 3, "block": 32, "support": 16, "pred_block": 24, "rank": 16},
    # AIMPEAK-like: 5-d features (MDS-embedded road network + time)
    "aimpeak": {"d": 5, "block": 200, "support": 128, "pred_block": 150,
                "rank": 128},
    # SARCOS-like: 21-d features (7 pos, 7 vel, 7 acc)
    "sarcos": {"d": 21, "block": 200, "support": 128, "pred_block": 150,
               "rank": 256},
}

FORBIDDEN_CALL_PREFIXES = ("lapack_", "cu", "hip")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_graph(name: str, profile: dict):
    fn, shapes = model.GRAPHS[name]
    specs = shapes(profile)
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    for bad in FORBIDDEN_CALL_PREFIXES:
        if f'custom_call_target="{bad}' in text.replace(" ", ""):
            raise RuntimeError(
                f"{name}: HLO contains a {bad}* custom-call; the rust "
                "runtime cannot execute it (use pure-jnp linalg in model.py)"
            )
    n_out = len(jax.eval_shape(fn, *specs))
    inputs = [[f"arg{i}", list(s.shape), str(s.dtype)]
              for i, s in enumerate(specs)]
    return text, inputs, n_out


def build(out_dir: str, profile_names: list[str]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"format": "hlo-text", "dtype": "float64",
                      "profiles": {}}
    for pname in profile_names:
        profile = PROFILES[pname]
        entry = {k: profile[k] for k in
                 ("d", "block", "support", "pred_block", "rank")}
        entry["graphs"] = {}
        for gname in model.GRAPHS:
            text, inputs, n_out = lower_graph(gname, profile)
            fname = f"{pname}_{gname}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            entry["graphs"][gname] = {
                "file": fname,
                "inputs": inputs,
                "outputs": n_out,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
            print(f"  [{pname}/{gname}] {len(text)} chars -> {fname}",
                  file=sys.stderr)
        manifest["profiles"][pname] = entry
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profiles", default=",".join(PROFILES),
                    help="comma-separated profile names")
    args = ap.parse_args()
    names = [p for p in args.profiles.split(",") if p]
    unknown = set(names) - set(PROFILES)
    if unknown:
        raise SystemExit(f"unknown profiles: {sorted(unknown)}")
    build(args.out_dir, names)
    print(f"wrote manifest for profiles {names} to {args.out_dir}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
