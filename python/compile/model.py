"""L2 — the paper's GP algebra as jitted JAX graphs, calling the L1 kernel.

Every graph here is lowered once by ``aot.py`` to HLO text and executed
from the rust coordinator via PJRT.  The graphs implement, block-wise, the
exact equations of Chen et al. (2013):

  * ``local_summary``    — Definition 2, eqs. (3)-(4): a machine's local
    summary ``(y_dot_S, Sigma_dot_SS)`` plus the cached Cholesky factor of
    ``Sigma_{D_m D_m | S}`` reused by the pPIC predictor.
  * ``ppitc_predict``    — Definition 4, eqs. (7)-(8) (diagonal variance).
  * ``ppic_predict``     — Definition 5, eqs. (12)-(14) (diagonal variance).
  * ``icf_local``        — Definition 6, eqs. (19)-(21).
  * ``icf_global``       — Definition 7, eqs. (22)-(23).
  * ``icf_predict``      — Definition 8, eqs. (24)-(25) (diagonal variance).

Conventions shared with the rust side (see rust/src/gp/):

  * zero prior mean — the coordinator centers outputs before calling in;
  * the paper's covariance function includes the noise term
    ``sn2 * delta``; hence any same-set covariance (``Sigma_BB``) carries
    ``+ sn2 I`` while cross-set blocks do not;
  * a relative jitter ``JITTER_SCALE * sf2`` is added to Cholesky inputs;
  * hyperparameters enter as one vector ``hyp = [log_ls (d), log_sf2,
    log_sn2]`` so learned values are supplied at run time.

IMPORTANT — no LAPACK custom-calls: on CPU, ``jnp.linalg.cholesky`` and
``solve_triangular`` lower to ``lapack_*_ffi`` custom-calls that the
standalone xla_extension runtime used by the rust binary cannot resolve.
All factorizations/solves below are pure-jnp ``fori_loop`` implementations
that lower to plain HLO (while / dynamic-update-slice / dot).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.se_gram import se_gram

JITTER_SCALE = 1e-8

__all__ = [
    "chol", "solve_lower", "solve_upper_t", "cho_solve",
    "cov", "cov_diag",
    "local_summary", "ppitc_predict", "ppic_predict",
    "icf_local", "icf_global", "icf_predict",
    "GRAPHS",
]


# --------------------------------------------------------------------------
# Pure-HLO dense linear algebra (no LAPACK custom-calls).
# --------------------------------------------------------------------------

def chol(a):
    """Lower-Cholesky factor of SPD ``a`` via a masked fori_loop.

    Right-looking unblocked algorithm; each of the n steps does O(n^2)
    vector work, lowering to a single HLO while-loop.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, a):
        d = jnp.sqrt(a[j, j])
        col = jnp.where(idx >= j, a[:, j] / d, 0.0)
        strict = idx > j
        upd = jnp.outer(col, col)
        mask = strict[:, None] & strict[None, :]
        a = a - jnp.where(mask, upd, 0.0)
        return a.at[:, j].set(col)

    a = jax.lax.fori_loop(0, n, body, a)
    return jnp.tril(a)


def solve_lower(l, b):
    """Solve ``L y = b`` (L lower-triangular) by forward substitution.

    ``b`` may be a vector ``(n,)`` or matrix ``(n, k)``.
    """
    n = l.shape[0]
    y = jnp.zeros_like(b)

    def body(i, y):
        s = l[i] @ y  # unsolved rows of y are still zero
        yi = (b[i] - s) / l[i, i]
        return y.at[i].set(yi)

    return jax.lax.fori_loop(0, n, body, y)


def solve_upper_t(l, y):
    """Solve ``L^T x = y`` by back substitution (L lower-triangular)."""
    n = l.shape[0]
    x = jnp.zeros_like(y)

    def body(t, x):
        i = n - 1 - t
        s = l[:, i] @ x
        xi = (y[i] - s) / l[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, n, body, x)


def cho_solve(l, b):
    """Solve ``(L L^T) x = b`` given the lower-Cholesky factor ``L``."""
    return solve_upper_t(l, solve_lower(l, b))


# --------------------------------------------------------------------------
# Covariance plumbing (L1 kernel entry points).
# --------------------------------------------------------------------------

def _split_hyp(hyp, d):
    return hyp[:d], hyp[d], hyp[d + 1]


def cov(x1, x2, hyp, *, same: bool, jitter: bool = False):
    """Prior covariance block ``Sigma_{B B'}`` per the paper's SE function.

    ``same=True`` adds the noise term ``sn2 I`` (Kronecker delta on
    coincident inputs); ``jitter=True`` additionally stabilizes a block
    that is about to be factorized.
    """
    d = x1.shape[1]
    log_ls, log_sf2, log_sn2 = _split_hyp(hyp, d)
    k = se_gram(x1, x2, log_ls, log_sf2)
    if same:
        bump = jnp.exp(log_sn2)
        if jitter:
            bump = bump + JITTER_SCALE * jnp.exp(log_sf2)
        k = k + bump * jnp.eye(x1.shape[0], dtype=k.dtype)
    elif jitter:
        k = k + JITTER_SCALE * jnp.exp(log_sf2) * jnp.eye(
            x1.shape[0], dtype=k.dtype)
    return k


def cov_diag(x, hyp):
    """Diagonal of ``Sigma_BB``: ``sf2 + sn2`` for every input."""
    d = x.shape[1]
    _, log_sf2, log_sn2 = _split_hyp(hyp, d)
    return jnp.full((x.shape[0],), jnp.exp(log_sf2) + jnp.exp(log_sn2),
                    dtype=x.dtype)


def _diag_ab(a, b):
    """diag(A @ B) for A (u, s), B (s, u) without forming the product."""
    return jnp.sum(a.T * b, axis=0)


# --------------------------------------------------------------------------
# pPITC / pPIC graphs (Section 3).
# --------------------------------------------------------------------------

def local_summary(xm, ym, xs, hyp):
    """Definition 2 — machine m's local summary w.r.t. support set S.

    Returns ``(y_dot_S, Sigma_dot_SS, L_m)`` where ``L_m`` is the
    Cholesky factor of ``Sigma_{D_m D_m | S}``, cached for pPIC.
    """
    k_ss = cov(xs, xs, hyp, same=True, jitter=True)
    l_ss = chol(k_ss)
    k_ms = cov(xm, xs, hyp, same=False)                    # (B, S)
    w = solve_lower(l_ss, k_ms.T)                          # (S, B)
    q_mm = w.T @ w                                         # Gamma_{mm}
    sigma_m = cov(xm, xm, hyp, same=True, jitter=True) - q_mm
    l_m = chol(sigma_m)                                    # (B, B)
    # one batched solve for [ym | K_ms]: halves the HLO while-loop count
    # vs two cho_solves (§Perf L2 iteration 1)
    rhs = jnp.concatenate([ym[:, None], k_ms], axis=1)     # (B, 1+S)
    sol = cho_solve(l_m, rhs)
    v, z = sol[:, 0], sol[:, 1:]
    y_dot = k_ms.T @ v                                     # (S,)  eq. (3)
    s_dot = k_ms.T @ z                                     # (S, S) eq. (4)
    return y_dot, s_dot, l_m


def ppitc_predict(xu, xs, y_glob, s_glob, hyp):
    """Definition 4 — pPITC predictive mean and (diagonal) variance."""
    k_us = cov(xu, xs, hyp, same=False)                    # (U, S)
    k_ss = cov(xs, xs, hyp, same=True, jitter=True)
    l_ss = chol(k_ss)
    l_g = chol(s_glob + JITTER_SCALE * jnp.eye(s_glob.shape[0],
                                               dtype=s_glob.dtype))
    # batch the l_g lower solves of [y_glob | K_su] (§Perf L2 iteration 1)
    rhs_g = jnp.concatenate([y_glob[:, None], k_us.T], axis=1)  # (S, 1+U)
    low_g = solve_lower(l_g, rhs_g)
    gy = solve_upper_t(l_g, low_g[:, 0])
    w2 = low_g[:, 1:]
    mu = k_us @ gy                                         # eq. (7)
    w1 = solve_lower(l_ss, k_us.T)                         # (S, U)
    var = cov_diag(xu, hyp) - jnp.sum(w1 * w1, axis=0) \
        + jnp.sum(w2 * w2, axis=0)                         # eq. (8) diag
    return mu, var


def ppic_predict(xu, xs, xm, ym, l_m, y_dot_m, s_dot_m, y_glob, s_glob, hyp):
    """Definition 5 — pPIC predictive mean and (diagonal) variance.

    ``l_m`` is the cached Cholesky factor of ``Sigma_{D_m D_m | S}`` from
    ``local_summary``; ``(y_dot_m, s_dot_m)`` the machine's own local
    summary; ``(y_glob, s_glob)`` the global summary.
    """
    k_us = cov(xu, xs, hyp, same=False)                    # (U, S)
    k_um = cov(xu, xm, hyp, same=False)                    # (U, B)
    k_ms = cov(xm, xs, hyp, same=False)                    # (B, S)
    k_ss = cov(xs, xs, hyp, same=True, jitter=True)
    l_ss = chol(k_ss)
    l_g = chol(s_glob + JITTER_SCALE * jnp.eye(s_glob.shape[0],
                                               dtype=s_glob.dtype))

    # Local-data terms (Definition 2 with B = U_m) — one batched solve
    # against l_m for [ym | K_ms | K_mu] (§Perf L2 iteration 1).
    b_rows = ym.shape[0]
    s_cols = k_ms.shape[1]
    rhs_m = jnp.concatenate([ym[:, None], k_ms, k_um.T], axis=1)
    sol_m = cho_solve(l_m, rhs_m)                          # (B, 1+S+U)
    v = sol_m[:, 0]
    z = sol_m[:, 1:1 + s_cols]
    t = sol_m[:, 1 + s_cols:]
    y_dot_u = k_um @ v                                     # y_dot_{U_m}^m
    s_dot_us = k_um @ z                                    # Sigma_dot_{U S}^m
    s_dot_uu_diag = jnp.sum(k_um.T * t, axis=0)            # diag Sigma_dot_UU
    del b_rows

    # batched l_ss solves: [Sdot_m | y_dot_m | K_su] share one factor
    rhs_ss = jnp.concatenate([s_dot_m, y_dot_m[:, None], k_us.T], axis=1)
    sol_ss = cho_solve(l_ss, rhs_ss)                       # (S, S+1+U)
    kss_inv_sdot = sol_ss[:, :s_cols]
    kss_inv_ydot = sol_ss[:, s_cols]
    p = sol_ss[:, s_cols + 1:]                             # Kss^-1 K_su

    # Phi_{U_m S}^m — eq. (14).
    phi_us = k_us + k_us @ kss_inv_sdot - s_dot_us         # (U, S)

    # Mean — eq. (12).
    mu = phi_us @ cho_solve(l_g, y_glob) \
        - k_us @ kss_inv_ydot + y_dot_u

    # Variance (diagonal) — eq. (13), *corrected*.  As printed, (13) omits
    # the global-summary term ``+ Phi Sigma_ddot^-1 Phi^T``; deriving the
    # variance directly from centralized PIC (16) via the same Woodbury
    # steps as the mean gives
    #   Sigma+ = Sigma_UU - Phi Kss^-1 K_su + K_us Kss^-1 Sdot_su
    #            - Sdot_UU + Phi Sddot^-1 Phi^T
    # and only this form satisfies Theorem 2 (verified in tests against a
    # literal numpy PIC).  See DESIGN.md "Paper erratum".
    diag1 = _diag_ab(phi_us, p)                            # diag(Phi Kss^-1 K_su)
    sdot_su = s_dot_us.T                                   # (S, U)
    diag2 = jnp.sum(k_us.T * cho_solve(l_ss, sdot_su), axis=0)
    w_g = solve_lower(l_g, phi_us.T)                       # (S, U)
    diag3 = jnp.sum(w_g * w_g, axis=0)                     # diag(Phi Sddot^-1 Phi^T)
    var = cov_diag(xu, hyp) - (diag1 - diag2) - s_dot_uu_diag + diag3
    return mu, var


# --------------------------------------------------------------------------
# pICF-based GP graphs (Section 4).
# --------------------------------------------------------------------------

def icf_local(xm, ym, xu, f_m, hyp):
    """Definition 6 — machine m's ICF local summary.

    ``f_m`` is machine m's (R, B) slab of the incomplete Cholesky factor
    of the *noise-free* Gram matrix K_DD (the paper's
    ``Sigma_DD ~ F^T F + sn2 I``).
    """
    y_dot = f_m @ ym                                       # (R,)  eq. (19)
    k_mu = cov(xm, xu, hyp, same=False)                    # (B, U)
    s_dot = f_m @ k_mu                                     # (R, U) eq. (20)
    phi_m = f_m @ f_m.T                                    # (R, R) eq. (21)
    return y_dot, s_dot, phi_m


def icf_global(sum_y_dot, sum_s_dot, sum_phi, hyp):
    """Definition 7 — the master's global summary.

    ``Phi = I + sn^-2 sum Phi_m``;   ``y_glob = Phi^-1 sum y_dot_m``;
    ``S_glob = Phi^-1 sum s_dot_m``.
    """
    r = sum_phi.shape[0]
    # hyp layout is [log_ls(d), log_sf2, log_sn2] — noise is hyp[-1].
    inv_sn2 = jnp.exp(-hyp[-1])
    phi = jnp.eye(r, dtype=sum_phi.dtype) + inv_sn2 * sum_phi
    l_phi = chol(phi)
    y_glob = cho_solve(l_phi, sum_y_dot)                   # eq. (22)
    s_glob = cho_solve(l_phi, sum_s_dot)                   # eq. (23)
    return y_glob, s_glob


def icf_predict(xu, xm, ym, s_dot_m, y_glob, s_glob, hyp):
    """Definition 8 — machine m's predictive component (diagonal var)."""
    d = xu.shape[1]
    inv_sn2 = jnp.exp(-hyp[d + 1])
    k_um = cov(xu, xm, hyp, same=False)                    # (U, B)
    mu_m = inv_sn2 * (k_um @ ym) \
        - inv_sn2 * inv_sn2 * (s_dot_m.T @ y_glob)         # eq. (24)
    var_m = inv_sn2 * jnp.sum(k_um * k_um, axis=1) \
        - inv_sn2 * inv_sn2 * jnp.sum(s_dot_m * s_glob, axis=0)  # eq. (25)
    return mu_m, var_m


# --------------------------------------------------------------------------
# AOT graph registry: name -> (fn, shape builder).
#
# The shape builder receives the profile dict (d, block B, support S,
# pred_block U, rank R) and returns the input ShapeDtypeStructs in call
# order.  All artifacts are f64.
# --------------------------------------------------------------------------

def _f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


GRAPHS = {
    "local_summary": (
        local_summary,
        lambda p: (
            _f64(p["block"], p["d"]), _f64(p["block"]),
            _f64(p["support"], p["d"]), _f64(p["d"] + 2),
        ),
    ),
    "ppitc_predict": (
        ppitc_predict,
        lambda p: (
            _f64(p["pred_block"], p["d"]), _f64(p["support"], p["d"]),
            _f64(p["support"]), _f64(p["support"], p["support"]),
            _f64(p["d"] + 2),
        ),
    ),
    "ppic_predict": (
        ppic_predict,
        lambda p: (
            _f64(p["pred_block"], p["d"]), _f64(p["support"], p["d"]),
            _f64(p["block"], p["d"]), _f64(p["block"]),
            _f64(p["block"], p["block"]), _f64(p["support"]),
            _f64(p["support"], p["support"]), _f64(p["support"]),
            _f64(p["support"], p["support"]), _f64(p["d"] + 2),
        ),
    ),
    "icf_local": (
        icf_local,
        lambda p: (
            _f64(p["block"], p["d"]), _f64(p["block"]),
            _f64(p["pred_block"], p["d"]), _f64(p["rank"], p["block"]),
            _f64(p["d"] + 2),
        ),
    ),
    "icf_global": (
        icf_global,
        lambda p: (
            _f64(p["rank"]), _f64(p["rank"], p["pred_block"]),
            _f64(p["rank"], p["rank"]), _f64(p["d"] + 2),
        ),
    ),
    "icf_predict": (
        icf_predict,
        lambda p: (
            _f64(p["pred_block"], p["d"]), _f64(p["block"], p["d"]),
            _f64(p["block"]), _f64(p["rank"], p["pred_block"]),
            _f64(p["rank"]), _f64(p["rank"], p["pred_block"]),
            _f64(p["d"] + 2),
        ),
    ),
}
