"""Build-time compile package: L1 Pallas kernels + L2 JAX graphs + AOT.

GP regression needs double precision — enable x64 before anything touches
jax so every graph, test and artifact is f64.
"""

import jax

jax.config.update("jax_enable_x64", True)
