"""Pure-jnp oracle for the L1 SE-Gram Pallas kernel.

This is the correctness reference: ``se_gram_ref`` computes the same ARD
squared-exponential covariance with no tiling, no expansion trick (it uses
the numerically-direct difference form), and no Pallas.  pytest asserts the
Pallas kernel against this for a hypothesis-driven sweep of shapes, dtypes
and hyperparameters.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["se_gram_ref", "se_gram_scaled_ref", "se_cov_full_ref"]


def se_gram_scaled_ref(x1, x2):
    """``exp(-0.5 * |x1_i - x2_j|^2)`` via explicit differences."""
    diff = x1[:, None, :] - x2[None, :, :]  # (n1, n2, d)
    sq = jnp.sum(diff * diff, axis=-1)
    return jnp.exp(-0.5 * sq)


def se_gram_ref(x1, x2, log_ls, log_sf2):
    """ARD SE Gram matrix (noise-free), direct-difference form."""
    inv_ls = jnp.exp(-log_ls)
    return jnp.exp(log_sf2) * se_gram_scaled_ref(x1 * inv_ls, x2 * inv_ls)


def se_cov_full_ref(x1, x2, log_ls, log_sf2, log_sn2, same: bool):
    """Full prior covariance including the Kronecker-delta noise term.

    ``same=True`` means x1 and x2 index the same point set, so the noise
    variance is added on the diagonal (the paper's sigma_n^2 * delta).
    """
    k = se_gram_ref(x1, x2, log_ls, log_sf2)
    if same:
        k = k + jnp.exp(log_sn2) * jnp.eye(x1.shape[0], dtype=k.dtype)
    return k
