"""L1 — tiled squared-exponential (SE) Gram-matrix kernel in Pallas.

This is the compute hot-spot shared by every GP method in the paper
(FGP, PITC/PIC, ICF and their parallel counterparts): all of them spend
their leading dense-algebra term building covariance blocks
``K[i, j] = sf2 * exp(-0.5 * sum_k ((x1[i,k] - x2[j,k]) / ls[k])^2)``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the output is tiled into
``(T1, T2)`` blocks by a 2-d grid; each grid step holds one ``(T1, d)`` and
one ``(T2, d)`` input row-block plus the output tile in VMEM.  The pairwise
squared distance uses the expansion trick
``|a-b|^2 = |a|^2 + |b|^2 - 2 a.b`` so the inner loop is a small matmul
(MXU-eligible at larger d) plus fully vectorized VPU work (mul/add/exp).

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the kernel is lowered to plain HLO ops.  The same
code path compiles for real TPUs by flipping the flag.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["se_gram", "se_gram_scaled", "pick_tile"]

# Default tile edge.  128 matches the TPU lane width; on CPU (interpret
# mode) it simply bounds the working set of one grid step.
DEFAULT_TILE = 128


def pick_tile(n: int, target: int = DEFAULT_TILE) -> int:
    """Largest divisor of ``n`` that is <= ``target``.

    Pallas grids must tile the array exactly; shapes in this project are
    pinned by the AOT manifest, so we only need *a* divisor, preferring
    large tiles for fewer grid steps.
    """
    if n <= 0:
        raise ValueError(f"tile target for non-positive n={n}")
    t = min(n, target)
    while n % t != 0:
        t -= 1
    return t


def _gram_tile_kernel(x1_ref, x2_ref, o_ref):
    """One (T1, T2) output tile of exp(-0.5 * pairwise_sqdist)."""
    x1 = x1_ref[...]  # (T1, d) — pre-scaled by 1/lengthscale
    x2 = x2_ref[...]  # (T2, d)
    s1 = jnp.sum(x1 * x1, axis=1, keepdims=True)  # (T1, 1)
    s2 = jnp.sum(x2 * x2, axis=1, keepdims=True)  # (T2, 1)
    cross = jnp.dot(x1, x2.T, preferred_element_type=x1.dtype)  # (T1, T2)
    sq = s1 + s2.T - 2.0 * cross
    # The expansion trick can go slightly negative for coincident points.
    sq = jnp.maximum(sq, 0.0)
    o_ref[...] = jnp.exp(-0.5 * sq)


@functools.partial(jax.jit, static_argnames=("tile1", "tile2", "interpret"))
def se_gram_scaled(x1, x2, *, tile1: int | None = None,
                   tile2: int | None = None, interpret: bool = True):
    """``exp(-0.5 * |x1_i - x2_j|^2)`` for pre-scaled inputs.

    Args:
      x1: ``(n1, d)`` inputs already divided by the ARD length-scales.
      x2: ``(n2, d)`` likewise.
      tile1/tile2: output tile edges; must divide n1/n2 (default: largest
        divisor <= 128).
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      ``(n1, n2)`` unit-variance SE Gram matrix.
    """
    n1, d = x1.shape
    n2, d2 = x2.shape
    if d != d2:
        raise ValueError(f"feature dims differ: {d} vs {d2}")
    t1 = tile1 if tile1 is not None else pick_tile(n1)
    t2 = tile2 if tile2 is not None else pick_tile(n2)
    if n1 % t1 or n2 % t2:
        raise ValueError(f"tiles ({t1},{t2}) must divide shape ({n1},{n2})")
    grid = (n1 // t1, n2 // t2)
    return pl.pallas_call(
        _gram_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t1, d), lambda i, j: (i, 0)),
            pl.BlockSpec((t2, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((t1, t2), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, n2), x1.dtype),
        interpret=interpret,
    )(x1, x2)


def se_gram(x1, x2, log_ls, log_sf2, *, tile1=None, tile2=None,
            interpret: bool = True):
    """Full ARD squared-exponential Gram matrix (noise-free).

    ``K[i, j] = exp(log_sf2) * exp(-0.5 * sum_k ((x1[i,k]-x2[j,k]) *
    exp(-log_ls[k]))^2)``.

    The noise term ``sn2 * I`` of the paper's covariance function is a
    *diagonal* correction applied by the callers (L2 graphs) only where
    x1 and x2 index the same point set.
    """
    inv_ls = jnp.exp(-log_ls)  # (d,)
    k = se_gram_scaled(x1 * inv_ls, x2 * inv_ls, tile1=tile1, tile2=tile2,
                       interpret=interpret)
    return jnp.exp(log_sf2) * k
